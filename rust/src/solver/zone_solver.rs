//! Per-zone collision resolution (paper §5, Eq. 6):
//!
//!   minimize ½·(q−q′)ᵀ·M̂·(q−q′)   subject to   C(q′) ≥ 0,
//!
//! where q stacks the zone's generalized coordinates (6 per rigid body,
//! 3 per cloth node) and each constraint is a VF/EE non-penetration gap
//! C_j(q′) = n_j · Σ_k w_jk·x_k(q′) − δ (Eq. 4) with x_k = f(q′) for
//! rigid vertices — *nonlinear* through the rotation (the reason the
//! paper extends Liang et al.'s linear-constraint differentiation, §6).
//!
//! Solved with an augmented-Lagrangian Gauss–Newton: robust, produces the
//! KKT multipliers λ* that the implicit-differentiation backward (§6)
//! needs.
//!
//! Memory: the solver's per-iteration temporaries live in the
//! thread-local [`crate::util::scratch`] arena, while the problem's own
//! state (`q0`, M̂) can be loaned from a cross-scene
//! [`BatchArena`] via [`ZoneProblem::build_in`] and handed back with
//! [`ZoneProblem::retire`] — see the engine's scatter/commit stages.
//! Both reuse paths are bitwise-neutral.

use crate::bodies::{NodeRef, System};
use crate::collision::zones::{entity_of, Entity, ImpactZone};
use crate::collision::Impact;
use crate::math::dense::Mat;
use crate::math::{euler, simd, Vec3};
use crate::util::arena::BatchArena;
use crate::util::memory::MemCategory;
use crate::util::scratch;

/// One term of a constraint row: how one of the four impact nodes maps
/// to zone DOFs. Fixed nodes fold into the constant part.
#[derive(Clone, Copy, Debug)]
pub enum Term {
    /// Vertex of a movable rigid body in the zone: x = f(q_ent, p0).
    RigidVert { ent: usize, w: f64, p0: Vec3 },
    /// Movable cloth node: x = q_ent directly.
    ClothNode { ent: usize, w: f64 },
}

/// A non-penetration constraint C(q′) = const + Σ terms − δ ≥ 0.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub n: Vec3,
    pub terms: Vec<Term>,
    /// n·Σ_{fixed k} w_k·x_k — contribution of immovable nodes.
    pub fixed_part: f64,
    /// Contact offset δ.
    pub delta: f64,
    /// The impact's surface-node quadruple — the constraint's identity
    /// across steps, used to match parked multipliers when warm-starting.
    pub nodes: [crate::bodies::NodeRef; 4],
}

/// Structure-of-arrays view of the constraints' *cloth* terms, grouped
/// per constraint row (CSR-style `cloth_ptr`). Cloth terms are linear —
/// coefficient w·n against three contiguous DOFs — so the
/// [`SimdMode::Fast`](simd::SimdMode::Fast) eval/Jacobian paths stream
/// them through [`simd::F64x4`] lanes with the per-component products
/// `w·n.x`, `w·n.y`, `w·n.z` precomputed once at build. Rigid terms stay
/// in AoS form: each one runs the full Euler-angle kinematics chain and
/// has no lane-parallel structure at zone sizes.
#[derive(Clone, Debug, Default)]
pub struct TermSoa {
    /// Row pointers: constraint `j`'s cloth terms are
    /// `cloth_off/cx/cy/cz[cloth_ptr[j]..cloth_ptr[j+1]]`.
    pub cloth_ptr: Vec<u32>,
    /// Stacked DOF offset of each cloth term's node (x component; the
    /// y/z DOFs are at `+1`/`+2`).
    pub cloth_off: Vec<u32>,
    /// Per-term coefficient w·n.x (exactly the product the scalar
    /// Jacobian writes).
    pub cloth_cx: Vec<f64>,
    /// Per-term coefficient w·n.y.
    pub cloth_cy: Vec<f64>,
    /// Per-term coefficient w·n.z.
    pub cloth_cz: Vec<f64>,
}

impl TermSoa {
    /// Build the SoA view from constraint rows (`offsets` maps entity
    /// slots to stacked DOF offsets, as in [`ZoneProblem::offsets`]).
    pub fn build(constraints: &[Constraint], offsets: &[usize]) -> TermSoa {
        let mut soa = TermSoa::default();
        soa.cloth_ptr.reserve(constraints.len() + 1);
        soa.cloth_ptr.push(0);
        for c in constraints {
            for t in &c.terms {
                if let Term::ClothNode { ent, w } = *t {
                    soa.cloth_off.push(offsets[ent] as u32);
                    soa.cloth_cx.push(w * c.n.x);
                    soa.cloth_cy.push(w * c.n.y);
                    soa.cloth_cz.push(w * c.n.z);
                }
            }
            soa.cloth_ptr.push(soa.cloth_off.len() as u32);
        }
        soa
    }

    /// Gap contribution of constraint `j`'s cloth block at `q`:
    /// Σ_t (cx·qx + cy·qy + cz·qz), four terms per lane step with the
    /// [`simd`] reduction tree, remainder in scalar order.
    fn row_dot(&self, j: usize, q: &[f64]) -> f64 {
        let (lo, hi) = (self.cloth_ptr[j] as usize, self.cloth_ptr[j + 1] as usize);
        let n = hi - lo;
        let main = lo + (n - n % simd::LANES);
        let mut acc = simd::F64x4::zero();
        let mut k = lo;
        while k < main {
            let o = [
                self.cloth_off[k] as usize,
                self.cloth_off[k + 1] as usize,
                self.cloth_off[k + 2] as usize,
                self.cloth_off[k + 3] as usize,
            ];
            let gx = simd::F64x4([q[o[0]], q[o[1]], q[o[2]], q[o[3]]]);
            let gy = simd::F64x4([q[o[0] + 1], q[o[1] + 1], q[o[2] + 1], q[o[3] + 1]]);
            let gz = simd::F64x4([q[o[0] + 2], q[o[1] + 2], q[o[2] + 2], q[o[3] + 2]]);
            acc = acc
                + simd::F64x4::load(&self.cloth_cx[k..]) * gx
                + simd::F64x4::load(&self.cloth_cy[k..]) * gy
                + simd::F64x4::load(&self.cloth_cz[k..]) * gz;
            k += simd::LANES;
        }
        let mut s = acc.hsum();
        for t in main..hi {
            let off = self.cloth_off[t] as usize;
            s += self.cloth_cx[t] * q[off]
                + self.cloth_cy[t] * q[off + 1]
                + self.cloth_cz[t] * q[off + 2];
        }
        s
    }
}

/// The zone optimization problem (Eq. 6) in stacked coordinates.
pub struct ZoneProblem {
    pub entities: Vec<Entity>,
    /// DOF offset per entity.
    pub offsets: Vec<usize>,
    /// Total DOFs n.
    pub n: usize,
    /// Stacked pre-projection coordinates q (candidate state).
    pub q0: Vec<f64>,
    /// Block-diagonal M̂ (dense; zones are small by construction).
    pub mass: Mat,
    pub constraints: Vec<Constraint>,
    /// SoA view of the cloth terms for the lane kernels — derived from
    /// `constraints`; call [`ZoneProblem::rebuild_soa`] after mutating
    /// them by hand.
    pub soa: TermSoa,
    /// Optional initial multipliers (one per constraint) from a previous
    /// step's parked solution. `None` (the default) reproduces the cold
    /// start bitwise; `Some` seeds the AL outer loop so persistent
    /// contacts converge in fewer Gauss-Newton iterations.
    pub warm_lambda: Option<Vec<f64>>,
}

/// Tuning knobs for a zone solve — the engine's fail-safe retry ladder
/// re-solves diverged zones with these escalated. [`SolveOpts::default`]
/// selects the exact arithmetic of [`ZoneProblem::solve`]: the default
/// path takes no extra branches through boosted code, so un-escalated
/// solves are bitwise-identical to a tree without the knobs.
#[derive(Clone, Copy, Debug)]
pub struct SolveOpts {
    /// Multiplies the initial AL penalty μ₀ *and* its growth cap.
    /// 1.0 = the stock schedule.
    pub mu_scale: f64,
    /// Extra Tikhonov regularization added to every diagonal entry of
    /// M̂ for the duration of the solve (stabilizes near-singular zone
    /// Hessians). 0.0 = the stock matrix, untouched.
    pub extra_reg: f64,
}

impl Default for SolveOpts {
    fn default() -> SolveOpts {
        SolveOpts { mu_scale: 1.0, extra_reg: 0.0 }
    }
}

/// Result of a zone solve.
#[derive(Clone, Debug)]
pub struct ZoneSolution {
    /// Resolved coordinates q′ (stacked like `q0`).
    pub q: Vec<f64>,
    /// KKT multipliers λ* ≥ 0, one per constraint.
    pub lambda: Vec<f64>,
    pub converged: bool,
    pub outer_iters: usize,
    /// Accepted Gauss–Newton steps summed over all outer AL rounds —
    /// the solver-health number the telemetry layer aggregates
    /// (`solver.gn_iters`; 0 for solutions produced off the native
    /// path, e.g. PJRT forward solves).
    pub gn_iters: usize,
    /// max(0, −C_j) at the solution.
    pub max_violation: f64,
}

impl ZoneSolution {
    /// Is the solution numerically sound — finite coordinates,
    /// multipliers, and violation? `false` marks a divergent solve the
    /// engine's fallible paths must not scatter (the `zone.solve`
    /// injection site forces this by setting an infinite violation).
    pub fn is_finite(&self) -> bool {
        self.max_violation.is_finite()
            && self.q.iter().all(|x| x.is_finite())
            && self.lambda.iter().all(|x| x.is_finite())
    }
}

impl ZoneProblem {
    /// Build from an impact zone. `rigid_q` / `cloth_x` hold *candidate*
    /// (post-dynamics, pre-resolution) coordinates for every body.
    pub fn build(
        sys: &System,
        zone: &ImpactZone,
        rigid_q: &[[f64; 6]],
        cloth_x: &[Vec<Vec3>],
        delta: f64,
    ) -> ZoneProblem {
        ZoneProblem::build_in(sys, zone, rigid_q, cloth_x, delta, &BatchArena::disabled())
    }

    /// [`ZoneProblem::build`] with the stacked coordinates `q0` and the
    /// zone mass matrix M̂ — the n + n² doubles that dominate a zone's
    /// footprint — loaned from a [`BatchArena`] under
    /// [`MemCategory::Solver`]. Loans are zero-filled before the same
    /// writes as the allocating path, so the problem is bitwise-identical
    /// either way. The loan is handed back via [`ZoneProblem::retire`]
    /// (untaped steps) or [`crate::diff::tape::StepRecord::recycle`]
    /// (taped ones).
    pub fn build_in(
        sys: &System,
        zone: &ImpactZone,
        rigid_q: &[[f64; 6]],
        cloth_x: &[Vec<Vec3>],
        delta: f64,
        arena: &BatchArena,
    ) -> ZoneProblem {
        let mut offsets = Vec::with_capacity(zone.entities.len());
        let mut n = 0;
        for e in &zone.entities {
            offsets.push(n);
            n += e.dofs();
        }
        // lint:allow(no-bare-unwrap: every constraint entity is a zone member by construction)
        let slot = |e: &Entity| zone.entities.iter().position(|x| x == e).unwrap();
        // Stacked q0 and block mass.
        let mut q0 = arena.loan_f64_zeroed(n, MemCategory::Solver);
        let mut mass = Mat::from_vec(n, n, arena.loan_f64_zeroed(n * n, MemCategory::Solver));
        for (k, e) in zone.entities.iter().enumerate() {
            let off = offsets[k];
            match e {
                Entity::Rigid(b) => {
                    let body = &sys.rigids[*b as usize];
                    q0[off..off + 6].copy_from_slice(&rigid_q[*b as usize]);
                    // M̂ evaluated at the candidate orientation.
                    let mut tmp = body.clone();
                    tmp.q = rigid_q[*b as usize];
                    let mm = tmp.mass_matrix();
                    for i in 0..6 {
                        for j in 0..6 {
                            mass[(off + i, off + j)] = mm[(i, j)];
                        }
                    }
                    // Regularize the Euler block for near-degenerate T.
                    for i in 0..3 {
                        mass[(off + i, off + i)] += 1e-9;
                    }
                }
                Entity::ClothNode(c, nd) => {
                    let x = cloth_x[*c as usize][*nd as usize];
                    q0[off] = x.x;
                    q0[off + 1] = x.y;
                    q0[off + 2] = x.z;
                    let m = sys.cloths[*c as usize].node_mass[*nd as usize];
                    for i in 0..3 {
                        mass[(off + i, off + i)] = m;
                    }
                }
            }
        }
        // Constraints from impacts.
        let constraints: Vec<Constraint> = zone
            .impacts
            .iter()
            .map(|im| constraint_from_impact(sys, im, &slot, rigid_q, cloth_x, delta))
            .collect();
        let soa = TermSoa::build(&constraints, &offsets);
        ZoneProblem {
            entities: zone.entities.clone(),
            offsets,
            n,
            q0,
            mass,
            constraints,
            soa,
            warm_lambda: None,
        }
    }

    /// Re-derive the [`TermSoa`] view after `constraints`/`offsets` were
    /// mutated in place (tests and tape surgery; the engine paths build
    /// problems fresh each step).
    pub fn rebuild_soa(&mut self) {
        self.soa = TermSoa::build(&self.constraints, &self.offsets);
    }

    /// Evaluate all constraints at stacked coordinates `q`.
    pub fn eval(&self, q: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.constraints.len());
        self.eval_into(q, &mut out);
        out
    }

    /// [`ZoneProblem::eval`] into a caller-provided (scratch) buffer —
    /// no allocation when the buffer has capacity. Dispatches on the
    /// active [`simd::SimdMode`]: [`ZoneProblem::eval_scalar_into`]
    /// under `Scalar`/`Ordered` (term order preserved — bitwise),
    /// [`ZoneProblem::eval_fast_into`] under `Fast` (SoA cloth lanes;
    /// ULP-bounded per the [`simd`] contract).
    pub fn eval_into(&self, q: &[f64], out: &mut Vec<f64>) {
        if simd::reduce_lanes() {
            self.eval_fast_into(q, out)
        } else {
            self.eval_scalar_into(q, out)
        }
    }

    /// Scalar oracle: terms accumulate in constraint order, exactly the
    /// seed arithmetic.
    pub fn eval_scalar_into(&self, q: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.constraints
            .iter()
            .map(|c| {
                let mut v = c.fixed_part - c.delta;
                for t in &c.terms {
                    match *t {
                        Term::RigidVert { ent, w, p0 } => {
                            let off = self.offsets[ent];
                            // lint:allow(no-bare-unwrap: slice is exactly 6 wide)
                            let qb: [f64; 6] = q[off..off + 6].try_into().unwrap();
                            v += w * c.n.dot(euler::transform_point(&qb, p0));
                        }
                        Term::ClothNode { ent, w } => {
                            let off = self.offsets[ent];
                            v += w * c.n.dot(Vec3::new(q[off], q[off + 1], q[off + 2]));
                        }
                    }
                }
                v
            }));
    }

    /// Lane path: rigid terms run the scalar kinematics chain in term
    /// order, then the constraint's cloth block streams through the
    /// [`TermSoa`] four terms per lane step. Reassociates the per-row
    /// sum (rigid-then-cloth, lane tree), so agreement with the oracle
    /// is ULP-bounded, not bitwise.
    pub fn eval_fast_into(&self, q: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(self.constraints.len());
        for (j, c) in self.constraints.iter().enumerate() {
            let mut v = c.fixed_part - c.delta;
            for t in &c.terms {
                if let Term::RigidVert { ent, w, p0 } = *t {
                    let off = self.offsets[ent];
                    // lint:allow(no-bare-unwrap: slice is exactly 6 wide)
                    let qb: [f64; 6] = q[off..off + 6].try_into().unwrap();
                    v += w * c.n.dot(euler::transform_point(&qb, p0));
                }
            }
            // Rigid-only rows skip the add entirely (also dodges the
            // `-0.0 + 0.0` sign flip an unconditional `+ 0.0` invites).
            if self.soa.cloth_ptr[j] < self.soa.cloth_ptr[j + 1] {
                v += self.soa.row_dot(j, q);
            }
            out.push(v);
        }
    }

    /// Constraint Jacobian ∇C (m×n) at `q` — the paper's G·∇f.
    pub fn jacobian(&self, q: &[f64]) -> Mat {
        let mut jac = Mat::zeros(0, 0);
        self.jacobian_into(q, &mut jac);
        jac
    }

    /// [`ZoneProblem::jacobian`] into a caller-provided (scratch)
    /// matrix — resized and zeroed before accumulation, so results are
    /// bitwise-identical to the allocating version. Dispatches like
    /// [`ZoneProblem::eval_into`]; the fast path is *also* bitwise here
    /// (a constraint's terms hit disjoint column blocks per node, so
    /// reordering rigid-before-cloth never reorders adds into the same
    /// entry, and the SoA coefficients are the very products `w·n.x`
    /// the scalar path writes).
    pub fn jacobian_into(&self, q: &[f64], jac: &mut Mat) {
        if simd::reduce_lanes() {
            self.jacobian_fast_into(q, jac)
        } else {
            self.jacobian_scalar_into(q, jac)
        }
    }

    /// Scalar oracle: the seed's interleaved term loop, verbatim.
    pub fn jacobian_scalar_into(&self, q: &[f64], jac: &mut Mat) {
        let m = self.constraints.len();
        jac.reset(m, self.n);
        for (j, c) in self.constraints.iter().enumerate() {
            for t in &c.terms {
                match *t {
                    Term::RigidVert { ent, w, p0 } => {
                        let off = self.offsets[ent];
                        // lint:allow(no-bare-unwrap: slice is exactly 6 wide)
                        let qb: [f64; 6] = q[off..off + 6].try_into().unwrap();
                        let jf = euler::jacobian(&qb, p0);
                        for col in 0..6 {
                            jac[(j, off + col)] += w
                                * (c.n.x * jf[0][col] + c.n.y * jf[1][col] + c.n.z * jf[2][col]);
                        }
                    }
                    Term::ClothNode { ent, w } => {
                        let off = self.offsets[ent];
                        jac[(j, off)] += w * c.n.x;
                        jac[(j, off + 1)] += w * c.n.y;
                        jac[(j, off + 2)] += w * c.n.z;
                    }
                }
            }
        }
    }

    /// Lane-mode path: rigid terms as in the oracle, cloth entries
    /// scattered straight from the precomputed [`TermSoa`] coefficients
    /// (no per-call `w·n` recompute). Bitwise-identical to
    /// [`ZoneProblem::jacobian_scalar_into`] — see
    /// [`ZoneProblem::jacobian_into`].
    pub fn jacobian_fast_into(&self, q: &[f64], jac: &mut Mat) {
        let m = self.constraints.len();
        jac.reset(m, self.n);
        for (j, c) in self.constraints.iter().enumerate() {
            for t in &c.terms {
                if let Term::RigidVert { ent, w, p0 } = *t {
                    let off = self.offsets[ent];
                    // lint:allow(no-bare-unwrap: slice is exactly 6 wide)
                    let qb: [f64; 6] = q[off..off + 6].try_into().unwrap();
                    let jf = euler::jacobian(&qb, p0);
                    for col in 0..6 {
                        jac[(j, off + col)] +=
                            w * (c.n.x * jf[0][col] + c.n.y * jf[1][col] + c.n.z * jf[2][col]);
                    }
                }
            }
            let (lo, hi) = (self.soa.cloth_ptr[j] as usize, self.soa.cloth_ptr[j + 1] as usize);
            for t in lo..hi {
                let off = self.soa.cloth_off[t] as usize;
                jac[(j, off)] += self.soa.cloth_cx[t];
                jac[(j, off + 1)] += self.soa.cloth_cy[t];
                jac[(j, off + 2)] += self.soa.cloth_cz[t];
            }
        }
    }

    /// Augmented-Lagrangian Gauss–Newton solve of Eq. 6.
    ///
    /// The per-iteration temporaries (constraint values, Jacobian, AL
    /// Hessian, gradient) come from the thread-local scratch arena
    /// ([`crate::util::scratch`]): under the persistent pool each worker
    /// re-fills the same allocations across every zone it solves instead
    /// of reallocating ~m×n + n² doubles per Gauss–Newton iteration.
    /// Arithmetic is unchanged, so solutions stay bitwise-identical.
    pub fn solve(&self) -> ZoneSolution {
        self.solve_with(&SolveOpts::default())
    }

    /// [`ZoneProblem::solve`] with explicit [`SolveOpts`]. With default
    /// opts this *is* `solve` (bit for bit); the engine's retry ladder
    /// passes boosted opts when a zone diverged.
    ///
    /// Fault injection: when the `faultinject` feature is on and the
    /// `zone.solve` site is armed, the (otherwise real) solution is
    /// reported as diverged (`converged: false`, infinite violation) so
    /// recovery paths can be driven deterministically.
    pub fn solve_with(&self, opts: &SolveOpts) -> ZoneSolution {
        let mut sol = self.solve_impl(opts);
        if crate::util::faultinject::should_fire(crate::util::faultinject::site::ZONE_SOLVE) {
            sol.converged = false;
            sol.max_violation = f64::INFINITY;
        }
        sol
    }

    fn solve_impl(&self, opts: &SolveOpts) -> ZoneSolution {
        let m = self.constraints.len();
        let mut q = self.q0.clone();
        // Warm start seeds λ only (q starts from the candidate state as
        // always); `None` is the bitwise cold-start path.
        let mut lambda = match &self.warm_lambda {
            Some(w) if w.len() == m => w.clone(),
            _ => vec![0.0; m],
        };
        // Boosted-path state is built only when the knobs are actually
        // turned: the default path runs the stock arithmetic on the
        // stock matrix with no extra float ops.
        let boosted_mass = if opts.extra_reg > 0.0 {
            let mut mm = self.mass.clone();
            for i in 0..self.n {
                mm[(i, i)] += opts.extra_reg;
            }
            Some(mm)
        } else {
            None
        };
        let mass = boosted_mass.as_ref().unwrap_or(&self.mass);
        let mut mu = 10.0 * self.mass_scale();
        let mut mu_cap = 1e7 * self.mass_scale();
        if opts.mu_scale != 1.0 {
            mu *= opts.mu_scale;
            mu_cap *= opts.mu_scale;
        }
        let mut prev_viol = f64::MAX;
        let tol = 1e-10;
        let max_outer = 40;
        let mut c = scratch::f64s(0, 0.0);
        let mut jac = scratch::mat(0, 0);
        let mut h = scratch::mat(0, 0);
        let mut dq = scratch::f64s(0, 0.0);
        let mut grad = scratch::f64s(0, 0.0);
        let mut trial: Vec<f64> = Vec::with_capacity(self.n);
        let mut gn_iters = 0usize;
        for outer in 0..max_outer {
            // Inner Gauss–Newton minimization of the AL function.
            for _ in 0..25 {
                self.eval_into(&q, c.as_vec());
                self.jacobian_into(&q, &mut jac);
                // grad = M(q−q0) − Jᵀ·max(0, λ − μ·c)
                // (dq/grad/H updates run on simd kernels; all are
                // elementwise per row — `y -= x·f` ≡ `y += (−f)·x` and
                // `μ·ja·x` left-associates onto the hoisted `μ·ja` —
                // so the Scalar/Ordered arithmetic is the seed's, bit
                // for bit, and Fast only reassociates the reductions
                // inside eval/jacobian/matvec/dot.)
                dq.fill_with(q.iter().zip(&self.q0).map(|(a, b)| a - b));
                mass.matvec_into(&dq, grad.as_vec());
                let mut active = vec![false; m];
                for j in 0..m {
                    let force = (lambda[j] - mu * c[j]).max(0.0);
                    if force > 0.0 {
                        active[j] = true;
                        simd::axpy(-force, jac.row(j), &mut grad);
                    }
                }
                // H = M + μ·Σ_active JᵀJ
                h.copy_from(mass);
                for j in 0..m {
                    if active[j] {
                        for a in 0..self.n {
                            let ja = jac[(j, a)];
                            if ja == 0.0 {
                                continue;
                            }
                            simd::axpy(mu * ja, jac.row(j), h.row_mut(a));
                        }
                    }
                }
                let neg_grad: Vec<f64> = grad.iter().map(|g| -g).collect();
                let step = match h.chol_solve(&neg_grad) {
                    Some(s) => s,
                    None => h.lu_solve(&neg_grad).unwrap_or_else(|| vec![0.0; self.n]),
                };
                // Backtracking line search on the AL merit function —
                // Gauss–Newton steps through the rotation nonlinearity
                // can otherwise overshoot wildly. (Merit temporaries are
                // fresh scratch takes per call, so the closure doesn't
                // contend with the loop's held buffers.)
                let merit = |qq: &[f64]| -> f64 {
                    let mut cs = scratch::f64s(0, 0.0);
                    self.eval_into(qq, cs.as_vec());
                    let mut d = scratch::f64s(0, 0.0);
                    d.fill_with(qq.iter().zip(&self.q0).map(|(a, b)| a - b));
                    let mut md = scratch::f64s(0, 0.0);
                    mass.matvec_into(&d, md.as_vec());
                    let mut val = 0.5 * crate::math::dense::dot(&d, &md);
                    for (j, &cj) in cs.iter().enumerate() {
                        let t = lambda[j] - mu * cj;
                        if t > 0.0 {
                            val += (t * t - lambda[j] * lambda[j]) / (2.0 * mu);
                        } else {
                            val -= lambda[j] * lambda[j] / (2.0 * mu);
                        }
                    }
                    val
                };
                let m0 = merit(&q);
                let mut alpha = 1.0;
                let mut accepted = false;
                for _ in 0..12 {
                    trial.clear();
                    trial.extend(q.iter().zip(&step).map(|(qi, si)| qi + alpha * si));
                    if merit(&trial) <= m0 + 1e-12 * m0.abs() {
                        std::mem::swap(&mut q, &mut trial);
                        accepted = true;
                        break;
                    }
                    alpha *= 0.5;
                }
                if !accepted {
                    break; // stationary for this μ
                }
                gn_iters += 1;
                let step_norm = alpha * crate::math::dense::norm(&step);
                if step_norm < 1e-12 * (1.0 + crate::math::dense::norm(&q)) {
                    break;
                }
            }
            // Multiplier update + convergence check.
            self.eval_into(&q, c.as_vec());
            let mut viol: f64 = 0.0;
            for j in 0..m {
                lambda[j] = (lambda[j] - mu * c[j]).max(0.0);
                viol = viol.max(-c[j]);
            }
            let comp: f64 = (0..m).map(|j| (lambda[j] * c[j]).abs()).fold(0.0, f64::max);
            if viol < tol && comp < 1e-8 * (1.0 + self.mass_scale()) {
                return ZoneSolution {
                    q,
                    lambda,
                    converged: true,
                    outer_iters: outer + 1,
                    gn_iters,
                    max_violation: viol,
                };
            }
            if viol > 0.5 * prev_viol {
                // Cap μ: unbounded growth on (temporarily) infeasible
                // constraint sets drives the solution arbitrarily far
                // from q — accepting a small residual violation is the
                // fail-safe loop's job, not the penalty's.
                mu = (mu * 4.0).min(mu_cap);
            }
            prev_viol = viol;
        }
        self.eval_into(&q, c.as_vec());
        let viol = c.iter().map(|&x| (-x).max(0.0)).fold(0.0, f64::max);
        ZoneSolution {
            q,
            lambda,
            converged: viol < 1e-6,
            outer_iters: max_outer,
            gn_iters,
            max_violation: viol,
        }
    }

    /// Is the problem's CCD-derived data numerically sound — finite
    /// stacked candidates and finite constraint rows (normals, weights,
    /// rest positions, offsets)? `false` means collision detection
    /// produced garbage and a solve would be meaningless
    /// ([`crate::engine::SceneError::CcdFailure`]). The mass matrix is
    /// body-derived, not CCD-derived, and is not scanned.
    pub fn is_finite(&self) -> bool {
        self.q0.iter().all(|x| x.is_finite())
            && self.constraints.iter().all(|c| {
                c.n.is_finite()
                    && c.fixed_part.is_finite()
                    && c.delta.is_finite()
                    && c.terms.iter().all(|t| match *t {
                        Term::RigidVert { w, p0, .. } => w.is_finite() && p0.is_finite(),
                        Term::ClothNode { w, .. } => w.is_finite(),
                    })
            })
    }

    /// Characteristic mass for scaling penalties/tolerances.
    fn mass_scale(&self) -> f64 {
        let mut s = 0.0;
        let mut k = 0;
        for i in 0..self.n {
            s += self.mass[(i, i)];
            k += 1;
        }
        if k == 0 {
            1.0
        } else {
            s / k as f64
        }
    }

    /// KKT stationarity residual ‖M(q′−q) − Jᵀλ‖ (diagnostics / tests).
    pub fn kkt_residual(&self, sol: &ZoneSolution) -> f64 {
        let dq: Vec<f64> = sol.q.iter().zip(&self.q0).map(|(a, b)| a - b).collect();
        let mut r = self.mass.matvec(&dq);
        let jac = self.jacobian(&sol.q);
        for j in 0..self.constraints.len() {
            for col in 0..self.n {
                r[col] -= jac[(j, col)] * sol.lambda[j];
            }
        }
        crate::math::dense::norm(&r)
    }

    /// Logical bytes of the buffers [`ZoneProblem::build_in`] loans from
    /// the arena (q0 + M̂) — the amount charged to
    /// [`MemCategory::Solver`] while the problem is alive.
    pub fn loaned_bytes(&self) -> usize {
        8 * (self.n + self.n * self.n)
    }

    /// Hand the loaned buffers back to `arena`: releases the
    /// [`MemCategory::Solver`] charge and parks the `q0`/M̂ allocations
    /// for the next zone of a similar shape. A plain drop (and a no-op
    /// charge-wise) when the arena is disabled.
    pub fn retire(self, arena: &BatchArena) {
        let bytes = self.loaned_bytes();
        arena.uncharge(MemCategory::Solver, bytes);
        let ZoneProblem { q0, mass, .. } = self;
        arena.park_vec(q0);
        arena.park_vec(mass.data);
    }

    /// Write the resolved coordinates back into per-body candidate state.
    pub fn scatter(
        &self,
        sol: &ZoneSolution,
        rigid_q: &mut [[f64; 6]],
        cloth_x: &mut [Vec<Vec3>],
    ) {
        for (k, e) in self.entities.iter().enumerate() {
            let off = self.offsets[k];
            match e {
                Entity::Rigid(b) => {
                    rigid_q[*b as usize].copy_from_slice(&sol.q[off..off + 6]);
                }
                Entity::ClothNode(c, nd) => {
                    cloth_x[*c as usize][*nd as usize] =
                        Vec3::new(sol.q[off], sol.q[off + 1], sol.q[off + 2]);
                }
            }
        }
    }
}

fn constraint_from_impact(
    sys: &System,
    im: &Impact,
    slot: &dyn Fn(&Entity) -> usize,
    rigid_q: &[[f64; 6]],
    cloth_x: &[Vec<Vec3>],
    delta: f64,
) -> Constraint {
    let mut terms = Vec::with_capacity(4);
    let mut fixed_part = 0.0;
    for k in 0..4 {
        let node = im.nodes[k];
        let w = im.w[k];
        match entity_of(sys, node) {
            Some(e @ Entity::Rigid(b)) => {
                let vert = match node {
                    NodeRef::Rigid { vert, .. } => vert as usize,
                    _ => unreachable!(),
                };
                terms.push(Term::RigidVert {
                    ent: slot(&e),
                    w,
                    p0: sys.rigids[b as usize].mesh0.verts[vert],
                });
            }
            Some(e @ Entity::ClothNode(..)) => {
                terms.push(Term::ClothNode { ent: slot(&e), w });
            }
            None => {
                // Fixed node: fold its (candidate) position into the
                // constant part.
                let x = match node {
                    NodeRef::Rigid { body, vert } => {
                        let qb = rigid_q[body as usize];
                        let v0 = sys.rigids[body as usize].mesh0.verts[vert as usize];
                        euler::transform_point(&qb, v0)
                    }
                    NodeRef::Cloth { cloth, node } => cloth_x[cloth as usize][node as usize],
                };
                fixed_part += w * im.n.dot(x);
            }
        }
    }
    Constraint { n: im.n, terms, fixed_part, delta, nodes: im.nodes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{Cloth, RigidBody, System};
    use crate::collision::zones::build_zones;
    use crate::collision::{detect, surfaces_from_system};
    use crate::mesh::primitives::{box_mesh, cloth_grid, unit_box};

    /// Cube pushed 0.2 below a frozen ground plane; the zone solve must
    /// lift it back out with an (almost) pure translation.
    fn penetrating_cube_problem() -> (System, ZoneProblem) {
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(5.0, 0.5, 5.0)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 1.0, 0.0)),
        );
        // Candidate: cube sunk to y = 0.3 (bottom at -0.2 → 0.2 below ground).
        let mut rigid_q = [[0.0f64; 6]; 2].to_vec();
        rigid_q[0] = sys.rigids[0].q;
        rigid_q[1] = sys.rigids[1].q;
        rigid_q[1][4] = 0.3;
        let x1: Vec<Vec<Vec3>> = (0..2)
            .map(|b| {
                let mut tmp = sys.rigids[b].clone();
                tmp.q = rigid_q[b];
                tmp.world_verts()
            })
            .collect();
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        assert!(!impacts.is_empty());
        let zones = build_zones(&sys, &impacts);
        assert_eq!(zones.len(), 1);
        let zp = ZoneProblem::build(&sys, &zones[0], &rigid_q, &[], 1e-3);
        (sys, zp)
    }

    #[test]
    fn cube_pushed_out_of_ground() {
        let (_sys, zp) = penetrating_cube_problem();
        let sol = zp.solve();
        assert!(sol.converged, "violation {}", sol.max_violation);
        // All constraints satisfied.
        let c = zp.eval(&sol.q);
        for (j, cj) in c.iter().enumerate() {
            assert!(*cj > -1e-8, "constraint {j}: {cj}");
        }
        // The cube rose: its y translation ≈ 0.5 (bottom at ground + δ).
        let ent_y = zp
            .entities
            .iter()
            .position(|e| matches!(e, Entity::Rigid(1)))
            .unwrap();
        let y = sol.q[zp.offsets[ent_y] + 4];
        assert!(y > 0.49 && y < 0.52, "resolved y = {y}");
        // Minimal-displacement: rotation stays tiny.
        for a in 0..3 {
            assert!(sol.q[zp.offsets[ent_y] + a].abs() < 1e-3, "rotated");
        }
        // Multipliers: at least one active contact, all nonnegative.
        assert!(sol.lambda.iter().any(|&l| l > 0.0));
        assert!(sol.lambda.iter().all(|&l| l >= 0.0));
    }

    #[test]
    fn solve_with_default_opts_is_bitwise_solve() {
        let (_sys, zp) = penetrating_cube_problem();
        let a = zp.solve();
        let b = zp.solve_with(&SolveOpts::default());
        assert_eq!(a.q, b.q);
        assert_eq!(a.lambda, b.lambda);
        assert_eq!(a.gn_iters, b.gn_iters);
        assert_eq!(a.max_violation.to_bits(), b.max_violation.to_bits());
    }

    #[test]
    fn warm_start_converges_faster_within_tolerance() {
        let (_sys, zp) = penetrating_cube_problem();
        let cold = zp.solve();
        assert!(cold.converged);
        // Seed the same problem with the converged multipliers: the AL
        // outer loop should need strictly fewer GN iterations and land
        // within tolerance of the cold solution.
        let (_sys2, mut warm_zp) = penetrating_cube_problem();
        warm_zp.warm_lambda = Some(cold.lambda.clone());
        let warm = warm_zp.solve();
        assert!(warm.converged);
        assert!(
            warm.gn_iters < cold.gn_iters,
            "warm {} vs cold {} GN iterations",
            warm.gn_iters,
            cold.gn_iters
        );
        for i in 0..zp.n {
            assert!(
                (warm.q[i] - cold.q[i]).abs() < 1e-6,
                "dof {i}: warm {} vs cold {}",
                warm.q[i],
                cold.q[i]
            );
        }
        // A wrong-length seed is ignored — bitwise cold start.
        let (_sys3, mut bad_zp) = penetrating_cube_problem();
        bad_zp.warm_lambda = Some(vec![0.5; cold.lambda.len() + 3]);
        let bad = bad_zp.solve();
        assert_eq!(bad.q, cold.q);
        assert_eq!(bad.lambda, cold.lambda);
        assert_eq!(bad.gn_iters, cold.gn_iters);
    }

    #[test]
    fn boosted_opts_still_resolve_penetration() {
        // The retry ladder's escalated solve must remain a valid solver:
        // same constraint satisfaction, same qualitative answer.
        let (_sys, zp) = penetrating_cube_problem();
        let sol = zp.solve_with(&SolveOpts { mu_scale: 100.0, extra_reg: 1e-6 });
        let c = zp.eval(&sol.q);
        for (j, cj) in c.iter().enumerate() {
            assert!(*cj > -1e-6, "constraint {j}: {cj}");
        }
        let ent_y = zp.entities.iter().position(|e| matches!(e, Entity::Rigid(1))).unwrap();
        let y = sol.q[zp.offsets[ent_y] + 4];
        assert!(y > 0.49 && y < 0.52, "resolved y = {y}");
    }

    #[test]
    fn eval_and_jacobian_into_match_allocating_versions() {
        let (_sys, zp) = penetrating_cube_problem();
        let q: Vec<f64> = zp.q0.iter().map(|&x| x + 0.01).collect();
        let mut c = vec![9.0; 3]; // stale contents must be overwritten
        zp.eval_into(&q, &mut c);
        assert_eq!(c, zp.eval(&q));
        let mut jac = Mat::zeros(2, 2);
        jac[(0, 0)] = 5.0; // stale entry must not leak into the accumulation
        zp.jacobian_into(&q, &mut jac);
        assert_eq!(jac, zp.jacobian(&q));
    }

    #[test]
    fn kkt_residual_small_at_solution() {
        let (_sys, zp) = penetrating_cube_problem();
        let sol = zp.solve();
        let r = zp.kkt_residual(&sol);
        assert!(r < 1e-5 * (1.0 + zp.mass_scale()), "KKT residual {r}");
    }

    #[test]
    fn no_violation_means_no_motion() {
        // Candidate already satisfies all constraints → q′ = q, λ = 0.
        let (_sys, mut zp) = penetrating_cube_problem();
        // Shift candidate up so nothing penetrates.
        let ent = zp.entities.iter().position(|e| matches!(e, Entity::Rigid(1))).unwrap();
        zp.q0[zp.offsets[ent] + 4] = 0.7;
        let sol = zp.solve();
        assert!(sol.converged);
        for (a, b) in sol.q.iter().zip(&zp.q0) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(sol.lambda.iter().all(|&l| l < 1e-9));
    }

    #[test]
    fn heavier_body_moves_less() {
        // Two cubes overlapping: light vs heavy — resolution shifts the
        // light one further (mass-weighted minimal displacement).
        let mut sys = System::new();
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 10.0).with_position(Vec3::new(0.0, 1.2, 0.0)),
        );
        // Candidate: the heavy cube moves down to y = 0.9 (0.1 overlap).
        let mut rigid_q: Vec<[f64; 6]> = sys.rigids.iter().map(|b| b.q).collect();
        rigid_q[1][4] = 0.9;
        let x1: Vec<Vec<Vec3>> = (0..2)
            .map(|b| {
                let mut tmp = sys.rigids[b].clone();
                tmp.q = rigid_q[b];
                tmp.world_verts()
            })
            .collect();
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        assert!(!impacts.is_empty(), "cubes should overlap");
        let zones = build_zones(&sys, &impacts);
        let zp = ZoneProblem::build(&sys, &zones[0], &rigid_q, &[], 1e-3);
        let sol = zp.solve();
        assert!(sol.converged, "viol={}", sol.max_violation);
        let i_light = zp.entities.iter().position(|e| *e == Entity::Rigid(0)).unwrap();
        let i_heavy = zp.entities.iter().position(|e| *e == Entity::Rigid(1)).unwrap();
        let dy_light = (sol.q[zp.offsets[i_light] + 4] - zp.q0[zp.offsets[i_light] + 4]).abs();
        let dy_heavy = (sol.q[zp.offsets[i_heavy] + 4] - zp.q0[zp.offsets[i_heavy] + 4]).abs();
        assert!(
            dy_light > 3.0 * dy_heavy,
            "light moved {dy_light}, heavy moved {dy_heavy}"
        );
    }

    #[test]
    fn eval_fast_matches_scalar_on_rigid_zone() {
        // No cloth terms: the fast path is the same rigid chain in the
        // same order — bitwise. (Explicit `_scalar`/`_fast` variants;
        // the process-global mode is never touched, so this test is
        // safe under the parallel lib-test runner.)
        let (_sys, zp) = penetrating_cube_problem();
        let q: Vec<f64> = zp.q0.iter().enumerate().map(|(i, &x)| x + 0.003 * i as f64).collect();
        let (mut cs, mut cf) = (Vec::new(), Vec::new());
        zp.eval_scalar_into(&q, &mut cs);
        zp.eval_fast_into(&q, &mut cf);
        assert_eq!(cs.len(), cf.len());
        for (a, b) in cs.iter().zip(&cf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let (mut js, mut jf) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        zp.jacobian_scalar_into(&q, &mut js);
        zp.jacobian_fast_into(&q, &mut jf);
        assert_eq!(js, jf);
    }

    /// Synthetic all-cloth zone: `m` constraints over `nodes` cloth
    /// nodes with `terms_per` cloth terms each — exercises the SoA lane
    /// blocks including the `terms_per % 4 != 0` remainder.
    fn synthetic_cloth_problem(nodes: usize, m: usize, terms_per: usize) -> ZoneProblem {
        assert!(terms_per <= nodes);
        let entities: Vec<Entity> = (0..nodes).map(|k| Entity::ClothNode(0, k as u32)).collect();
        let offsets: Vec<usize> = (0..nodes).map(|k| 3 * k).collect();
        let n = 3 * nodes;
        let q0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.731).sin()).collect();
        let constraints: Vec<Constraint> = (0..m)
            .map(|j| {
                let raw = Vec3::new(
                    (j as f64 + 1.0).sin(),
                    (j as f64 * 1.7 + 0.3).cos(),
                    (j as f64 * 0.9 - 1.0).sin(),
                );
                let nrm = raw.normalized();
                let terms = (0..terms_per)
                    .map(|t| Term::ClothNode {
                        ent: (j + 3 * t) % nodes,
                        w: 0.25 + 0.5 * ((j + t) as f64 * 0.37).cos(),
                    })
                    .collect();
                Constraint {
                    n: nrm,
                    terms,
                    fixed_part: 0.01 * j as f64,
                    delta: 1e-3,
                    nodes: [NodeRef::Cloth { cloth: 0, node: j as u32 }; 4],
                }
            })
            .collect();
        let soa = TermSoa::build(&constraints, &offsets);
        ZoneProblem {
            entities,
            offsets,
            n,
            q0,
            mass: Mat::identity(n),
            constraints,
            soa,
            warm_lambda: None,
        }
    }

    #[test]
    fn eval_fast_cloth_lanes_within_ulp_bound() {
        // Cloth rows reassociate (per-component SoA products, lane
        // tree) — assert the documented bound instead of bitwise, for
        // term counts hitting full lanes, remainders, and empty rows.
        for terms_per in [0usize, 1, 3, 4, 5, 7, 8, 11] {
            let zp = synthetic_cloth_problem(12, 6, terms_per);
            let q: Vec<f64> =
                zp.q0.iter().enumerate().map(|(i, &x)| x + 0.1 * (i as f64).cos()).collect();
            let (mut cs, mut cf) = (Vec::new(), Vec::new());
            zp.eval_scalar_into(&q, &mut cs);
            zp.eval_fast_into(&q, &mut cf);
            assert_eq!(cs.len(), cf.len());
            for (j, (a, b)) in cs.iter().zip(&cf).enumerate() {
                // 2·n·ε·Σ|pᵢ| with n = 3 products per term and every
                // |w·n·q| ≤ 1 by construction (plus the constant part).
                let mag = 1.0 + 3.0 * terms_per as f64;
                let bound = 2.0 * (3 * terms_per.max(1)) as f64 * f64::EPSILON * mag;
                assert!(
                    (a - b).abs() <= bound,
                    "terms_per={terms_per} row {j}: scalar {a} fast {b} (bound {bound})"
                );
            }
            // The Jacobian stays bitwise even through the SoA path.
            let (mut js, mut jf) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
            zp.jacobian_scalar_into(&q, &mut js);
            zp.jacobian_fast_into(&q, &mut jf);
            assert_eq!(js, jf);
        }
    }

    #[test]
    fn rebuild_soa_tracks_constraint_edits() {
        let mut zp = synthetic_cloth_problem(8, 4, 5);
        zp.constraints.truncate(2);
        zp.constraints[0].terms.pop();
        zp.rebuild_soa();
        assert_eq!(zp.soa.cloth_ptr.len(), zp.constraints.len() + 1);
        let q = zp.q0.clone();
        let (mut cs, mut cf) = (Vec::new(), Vec::new());
        zp.eval_scalar_into(&q, &mut cs);
        zp.eval_fast_into(&q, &mut cf);
        for (a, b) in cs.iter().zip(&cf) {
            assert!((a - b).abs() <= 1e-12 * (1.0 + a.abs()));
        }
    }

    #[test]
    fn cloth_node_resolved_against_rigid() {
        let mut sys = System::new();
        sys.add_rigid(RigidBody::frozen_from_mesh(unit_box()));
        let cloth = Cloth::from_grid(
            cloth_grid(2, 2, 0.6, 0.6).translated(Vec3::new(0.0, 0.55, 0.0)),
            0.2,
            100.0,
            1.0,
            0.0,
        );
        sys.add_cloth(cloth);
        let rigid_q: Vec<[f64; 6]> = sys.rigids.iter().map(|b| b.q).collect();
        // Candidate: center node moves down through the cube's top face
        // (0.55 → 0.45, face at y = 0.5) — caught by CCD.
        let mut cloth_x = vec![sys.cloths[0].x.clone()];
        cloth_x[0][4].y = 0.45;
        let surfs = surfaces_from_system(&sys, &[sys.rigids[0].world_verts()], &cloth_x, 1e-3);
        let (impacts, _) = detect(&surfs, 1e-3);
        assert!(!impacts.is_empty());
        let zones = build_zones(&sys, &impacts);
        let zp = ZoneProblem::build(&sys, &zones[0], &rigid_q, &cloth_x, 1e-3);
        let sol = zp.solve();
        assert!(sol.converged);
        let c = zp.eval(&sol.q);
        assert!(c.iter().all(|&x| x > -1e-8));
        // The cloth node ends at/above the cube top.
        let mut rq = rigid_q.clone();
        let mut cx = cloth_x.clone();
        zp.scatter(&sol, &mut rq, &mut cx);
        assert!(cx[0][4].y >= 0.5 - 1e-6, "node y = {}", cx[0][4].y);
    }
}
