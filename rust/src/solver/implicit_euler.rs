//! Implicit Euler integration (paper §4, Eqs. 2–3).
//!
//! Cloth: with a linear approximation of f around (q₀, q̇₀), Eq. 3 becomes
//! (M − h·∂f/∂q̇ − h²·∂f/∂q)·Δq̇ = h·(f₀ + h·(∂f/∂q)·q̇₀), assembled as a
//! CSR system and solved with Jacobi-PCG. (That is Eq. 3 multiplied
//! through by h — better conditioned.)
//!
//! Rigid bodies: the generalized mass matrix M̂(q) (Appendix A) is dense
//! 6×6 per body and forces are configuration-independent (gravity,
//! control, explicit gyroscopic term), so each body solves its own 6×6
//! system M̂·Δq̇ = h·Q(q, q̇).
//!
//! Kernel modes: the CSR row products inside the PCG solve dispatch on
//! the active [`crate::math::simd::SimdMode`] — the solve is bitwise
//! reproducible under `Scalar`/`Ordered` and ULP-perturbed per CG
//! iteration under `Fast` (`tests/integration_simd.rs` holds the
//! full-step results to the documented tolerance).

use crate::bodies::{Cloth, RigidBody};
use crate::math::cg::pcg_csr;
use crate::math::sparse::{Csr, Triplets};
use crate::math::Vec3;
use crate::util::arena::BatchArena;

/// Outcome of a cloth implicit solve, retaining the operator for the
/// backward pass (implicit differentiation of the linear solve).
pub struct ClothSolve {
    /// Velocity increments per node.
    pub dv: Vec<Vec3>,
    /// The (symmetric) system matrix A = M − h·∂f/∂q̇ − h²·∂f/∂q.
    pub a: Csr,
    /// CG iterations used (diagnostics).
    pub iters: usize,
}

/// One implicit-Euler velocity update for a cloth (plain allocation —
/// [`cloth_implicit_step_in`] with a disabled arena).
pub fn cloth_implicit_step(cloth: &Cloth, h: f64, gravity: Vec3) -> ClothSolve {
    cloth_implicit_step_in(cloth, h, gravity, &BatchArena::disabled())
}

/// [`cloth_implicit_step`] with its buffers loaned from `arena`: the
/// retained system CSR `a` and the `dv` increments (both of which a
/// taped step keeps alive in a `ClothSolveRec` until
/// `StepRecord::recycle` hands them back at `clear_tape`), plus the
/// transient ∂f/∂x CSR, which is parked again before this function
/// returns. Loans go through [`BatchArena::loan_vec`] (uncharged — the
/// tape record accounts the retained bytes at commit), every buffer is
/// cleared and fully rebuilt, and a disabled arena makes this exactly
/// the plain-allocation solve — the solve is bitwise-identical in every
/// mode.
pub fn cloth_implicit_step_in(
    cloth: &Cloth,
    h: f64,
    gravity: Vec3,
    arena: &BatchArena,
) -> ClothSolve {
    let n = cloth.n_nodes();
    let dim = 3 * n;
    // ∂f/∂x (SPD-clamped for solvability) and diagonal ∂f/∂v.
    let mut dfdx = Triplets::new(dim, dim);
    let dfdv_diag = cloth.force_jacobian(&mut dfdx, 0, true);
    let jnnz = dfdx.nnz();
    let jx = dfdx.to_csr_into(
        arena.loan_vec(jnnz),
        arena.loan_vec(jnnz),
        arena.loan_vec(dim + 1),
    );
    // A = M − h·∂f/∂v − h²·∂f/∂x, b = h·(f0 + h·(∂f/∂x)·v0).
    let mut a_trip = Triplets::new(dim, dim);
    for i in 0..n {
        let m = if cloth.pinned[i] { 1.0 } else { cloth.node_mass[i] };
        let dv = if cloth.pinned[i] { 0.0 } else { dfdv_diag[i] };
        for c in 0..3 {
            a_trip.push(3 * i + c, 3 * i + c, m - h * dv);
        }
    }
    for r in 0..dim {
        for k in jx.indptr[r]..jx.indptr[r + 1] {
            a_trip.push(r, jx.indices[k] as usize, -h * h * jx.data[k]);
        }
    }
    let annz = a_trip.nnz();
    let a = a_trip.to_csr_into(
        arena.loan_vec(annz),
        arena.loan_vec(annz),
        arena.loan_vec(dim + 1),
    );
    let f0 = cloth.forces(gravity);
    let mut v0 = vec![0.0; dim];
    for i in 0..n {
        let v = if cloth.pinned[i] { Vec3::default() } else { cloth.v[i] };
        v0[3 * i] = v.x;
        v0[3 * i + 1] = v.y;
        v0[3 * i + 2] = v.z;
    }
    let jv = jx.matvec(&v0);
    // The transient Jacobian's buffers go straight back on the shelf
    // (its last use was the matvec above).
    let Csr { indptr, indices, data, .. } = jx;
    arena.park_vec(indptr);
    arena.park_vec(indices);
    arena.park_vec(data);
    let mut b = vec![0.0; dim];
    for i in 0..n {
        for c in 0..3 {
            b[3 * i + c] = if cloth.pinned[i] {
                0.0
            } else {
                h * (f0[i][c] + h * jv[3 * i + c])
            };
        }
    }
    let res = pcg_csr(&a, &b, 1e-9, 20 * dim.max(10));
    let mut dv: Vec<Vec3> = arena.loan_vec(n);
    dv.extend((0..n).map(|i| Vec3::new(res.x[3 * i], res.x[3 * i + 1], res.x[3 * i + 2])));
    ClothSolve { dv, a, iters: res.iters }
}

/// One implicit(-in-M̂) Euler velocity update for a rigid body:
/// M̂(q)·Δq̇ = h·Q with Q the generalized force (gravity + external +
/// explicit gyroscopic torque).
pub fn rigid_step(body: &RigidBody, h: f64, gravity: Vec3) -> [f64; 6] {
    rigid_step_damped(body, h, gravity, 0.0)
}

/// `rigid_step` with angular damping (see `generalized_force_damped`).
pub fn rigid_step_damped(
    body: &RigidBody,
    h: f64,
    gravity: Vec3,
    angular_damping: f64,
) -> [f64; 6] {
    if body.frozen {
        return [0.0; 6];
    }
    let m = body.mass_matrix();
    let q_gen = body.generalized_force_damped(gravity, angular_damping);
    let rhs: Vec<f64> = q_gen.iter().map(|f| h * f).collect();
    let sol = m
        .lu_solve(&rhs)
        .or_else(|| {
            // Near gimbal lock M̂ is singular in the Euler block —
            // regularize (the stepper also re-parameterizes).
            let mut mr = m.clone();
            for i in 0..3 {
                mr[(i, i)] += 1e-9 + 1e-6 * mr[(i, i)].abs();
            }
            mr.lu_solve(&rhs)
        })
        // lint:allow(no-bare-unwrap: regularized SPD mass matrix cannot be singular)
        .expect("rigid mass matrix unsolvable");
    [sol[0], sol[1], sol[2], sol[3], sol[4], sol[5]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bodies::{Cloth, RigidBody};
    use crate::mesh::primitives::{cloth_grid, unit_box};
    use crate::util::quick::quick;

    const G: Vec3 = Vec3 { x: 0.0, y: -9.8, z: 0.0 };

    #[test]
    fn free_fall_cloth_accelerates_at_g() {
        // No pins, no initial deformation: Δv = h·g exactly.
        let cloth = Cloth::from_grid(cloth_grid(4, 4, 1.0, 1.0), 0.2, 500.0, 2.0, 0.0);
        let s = cloth_implicit_step(&cloth, 0.01, G);
        for dv in &s.dv {
            assert!((*dv - G * 0.01).norm() < 1e-8, "{dv:?}");
        }
    }

    #[test]
    fn pinned_nodes_stay_put() {
        let mut cloth = Cloth::from_grid(cloth_grid(4, 4, 1.0, 1.0), 0.2, 500.0, 2.0, 0.0);
        cloth.pin(0);
        cloth.pin(4);
        let s = cloth_implicit_step(&cloth, 0.01, G);
        assert!(s.dv[0].norm() < 1e-12);
        assert!(s.dv[4].norm() < 1e-12);
        // Free nodes still fall.
        assert!(s.dv[12].y < -0.05);
    }

    #[test]
    fn hanging_cloth_reaches_equilibrium_velocity_zero() {
        // Pin two corners, simulate until drape stabilizes; velocities
        // must decay (implicit Euler is dissipative).
        let mut cloth = Cloth::from_grid(cloth_grid(6, 6, 1.0, 1.0), 0.2, 2000.0, 5.0, 0.5);
        cloth.pin(0);
        cloth.pin(6);
        let h = 0.02;
        for _ in 0..300 {
            let s = cloth_implicit_step(&cloth, h, G);
            for i in 0..cloth.n_nodes() {
                if !cloth.pinned[i] {
                    cloth.v[i] += s.dv[i];
                    let dx = cloth.v[i] * h;
                    cloth.x[i] += dx;
                }
            }
        }
        let vmax = cloth.v.iter().map(|v| v.norm()).fold(0.0, f64::max);
        assert!(vmax < 0.5, "cloth still moving fast: vmax={vmax}");
        // Cloth should hang below the pins.
        let ymin = cloth.x.iter().map(|p| p.y).fold(f64::MAX, f64::min);
        assert!(ymin < -0.3, "cloth did not drape: ymin={ymin}");
        // No explosion.
        for p in &cloth.x {
            assert!(p.is_finite());
            assert!(p.norm() < 10.0);
        }
    }

    #[test]
    fn stiff_cloth_stable_at_large_timestep() {
        // The point of implicit Euler: stability for stiff springs at
        // large h where explicit Euler would explode.
        let mut cloth = Cloth::from_grid(cloth_grid(8, 8, 1.0, 1.0), 0.1, 1e5, 10.0, 0.0);
        cloth.pin(0);
        cloth.pin(8);
        let h = 1.0 / 30.0;
        for _ in 0..60 {
            let s = cloth_implicit_step(&cloth, h, G);
            for i in 0..cloth.n_nodes() {
                if !cloth.pinned[i] {
                    cloth.v[i] += s.dv[i];
                    cloth.x[i] += cloth.v[i] * h;
                }
            }
            for p in &cloth.x {
                assert!(p.is_finite() && p.norm() < 100.0, "explosion");
            }
        }
    }

    #[test]
    fn arena_loaned_cloth_solve_is_bitwise_identical() {
        // Two consecutive solves on a pooled arena: the second reuses
        // the first's parked CSR buffers and must still match the
        // plain-allocation solve bit for bit.
        let mut cloth = Cloth::from_grid(cloth_grid(5, 5, 1.0, 1.0), 0.2, 800.0, 2.0, 0.3);
        cloth.pin(0);
        let arena = BatchArena::new();
        for round in 0..2 {
            let plain = cloth_implicit_step(&cloth, 0.01, G);
            let pooled = cloth_implicit_step_in(&cloth, 0.01, G, &arena);
            // Park the retained buffers like StepRecord::recycle would,
            // so round 1 exercises the reuse path.
            assert_eq!(plain.a.indptr, pooled.a.indptr, "round {round}");
            assert_eq!(plain.a.indices, pooled.a.indices, "round {round}");
            assert_eq!(plain.a.data, pooled.a.data, "round {round}");
            assert_eq!(plain.iters, pooled.iters, "round {round}");
            for (i, (x, y)) in plain.dv.iter().zip(&pooled.dv).enumerate() {
                assert!(
                    x.x == y.x && x.y == y.y && x.z == y.z,
                    "round {round} node {i}: plain {x:?} vs pooled {y:?}"
                );
            }
            let Csr { indptr, indices, data, .. } = pooled.a;
            arena.park_vec(indptr);
            arena.park_vec(indices);
            arena.park_vec(data);
            arena.park_vec(pooled.dv);
        }
        let s = arena.stats();
        assert!(s.hits > 0, "second round must reuse parked buffers: {s:?}");
    }

    #[test]
    fn rigid_free_fall() {
        let mut b = RigidBody::from_mesh(unit_box(), 1.0);
        let dqd = rigid_step(&b, 0.01, G);
        assert!((dqd[4] - (-0.098)).abs() < 1e-12);
        assert_eq!(dqd[0], 0.0);
        b.qdot[4] += dqd[4];
        assert!((b.linear_velocity().y + 0.098).abs() < 1e-12);
    }

    #[test]
    fn rigid_spin_conserves_angular_momentum() {
        quick("rigid-spin-L", 10, |g| {
            let mut b = RigidBody::from_mesh(
                crate::mesh::primitives::box_mesh(Vec3::new(0.3, 0.5, 0.2)),
                1.0,
            );
            b.qdot[0] = g.f64(-1.0, 1.0);
            b.qdot[1] = g.f64(-0.5, 0.5);
            b.qdot[2] = g.f64(-1.0, 1.0);
            let h = 1e-3;
            let l0 = b.inertia_world() * b.omega();
            for _ in 0..200 {
                if b.near_gimbal_lock() {
                    return; // stepper handles re-parameterization; skip here
                }
                let dqd = rigid_step(&b, h, Vec3::default());
                for k in 0..6 {
                    b.qdot[k] += dqd[k];
                    b.q[k] += h * b.qdot[k];
                }
            }
            let l1 = b.inertia_world() * b.omega();
            // First-order integrator: allow a few percent drift.
            assert!(
                (l1 - l0).norm() < 0.05 * (1.0 + l0.norm()),
                "L drift: {:?} -> {:?}",
                l0,
                l1
            );
        });
    }

    #[test]
    fn frozen_body_never_moves() {
        let b = RigidBody::frozen_from_mesh(unit_box());
        assert_eq!(rigid_step(&b, 0.01, G), [0.0; 6]);
    }
}
