//! Global LCP-style baseline (paper §7.2, Table 1): instead of resolving
//! each impact zone independently, merge every zone into ONE optimization
//! over all contacting bodies — the de Avila Belbute-Peres (2018)
//! formulation the paper ablates against. Forward cost and (especially)
//! implicit-diff backward cost then scale with the *total* DOF/constraint
//! count rather than per-zone sizes.
//!
//! Also provides a classic projected Gauss–Seidel velocity-level LCP used
//! as a cross-check on contact impulses.

use crate::collision::zones::ImpactZone;
use crate::math::dense::Mat;

/// Merge all impact zones into a single global zone (the baseline's
/// "one big optimization problem").
pub fn merge_zones(zones: &[ImpactZone]) -> Option<ImpactZone> {
    if zones.is_empty() {
        return None;
    }
    let mut impacts = Vec::new();
    let mut entities = Vec::new();
    for z in zones {
        impacts.extend(z.impacts.iter().copied());
        entities.extend(z.entities.iter().copied());
    }
    entities.sort();
    entities.dedup();
    Some(ImpactZone { impacts, entities })
}

/// Projected Gauss–Seidel on the velocity-level LCP:
///   w = B·λ + b ≥ 0, λ ≥ 0, λᵀw = 0,  with B = J·M⁻¹·Jᵀ.
/// Returns λ. `b` is typically J·v (normal approach velocities).
pub fn pgs_lcp(bmat: &Mat, b: &[f64], iters: usize) -> Vec<f64> {
    let m = b.len();
    assert_eq!(bmat.rows, m);
    let mut lambda = vec![0.0; m];
    for _ in 0..iters {
        for i in 0..m {
            let bii = bmat[(i, i)];
            if bii.abs() < 1e-300 {
                continue;
            }
            let mut s = b[i];
            for j in 0..m {
                if j != i {
                    s += bmat[(i, j)] * lambda[j];
                }
            }
            lambda[i] = (-s / bii).max(0.0);
        }
    }
    lambda
}

/// LCP residual: max over i of |min(λᵢ, (Bλ+b)ᵢ)| (complementarity).
pub fn lcp_residual(bmat: &Mat, b: &[f64], lambda: &[f64]) -> f64 {
    let w = {
        let mut w = bmat.matvec(lambda);
        for i in 0..w.len() {
            w[i] += b[i];
        }
        w
    };
    lambda
        .iter()
        .zip(&w)
        .map(|(&l, &wi)| l.min(wi).abs().max((-l).max(0.0)).max((-wi).max(0.0)))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::quick;

    #[test]
    fn pgs_solves_diagonal_lcp() {
        // B = I: λ = max(0, −b).
        let b = vec![1.0, -2.0, 0.5, -0.25];
        let bmat = Mat::identity(4);
        let l = pgs_lcp(&bmat, &b, 50);
        let want = [0.0, 2.0, 0.0, 0.25];
        for (got, w) in l.iter().zip(want) {
            assert!((got - w).abs() < 1e-9, "{got} vs {w}");
        }
    }

    #[test]
    fn pgs_satisfies_complementarity_on_random_spd() {
        quick("pgs-lcp", 40, |g| {
            let m = g.usize(1, 12);
            let a = Mat::from_vec(m, m, g.vec_normal(m * m));
            let bmat = a.transpose().matmul(&a).add(&Mat::identity(m).scale(m as f64));
            let b = g.vec_normal(m);
            let l = pgs_lcp(&bmat, &b, 2000);
            assert!(
                lcp_residual(&bmat, &b, &l) < 1e-6,
                "residual {}",
                lcp_residual(&bmat, &b, &l)
            );
        });
    }

    #[test]
    fn merge_zones_unions_entities() {
        use crate::bodies::{RigidBody, System};
        use crate::collision::zones::{build_zones, Entity};
        use crate::collision::Impact;
        use crate::bodies::NodeRef;
        use crate::math::Vec3;
        use crate::mesh::primitives::unit_box;
        let mut sys = System::new();
        for _ in 0..4 {
            sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0));
        }
        let mk = |a: u32, b: u32| Impact {
            nodes: [
                NodeRef::Rigid { body: a, vert: 0 },
                NodeRef::Rigid { body: a, vert: 1 },
                NodeRef::Rigid { body: a, vert: 2 },
                NodeRef::Rigid { body: b, vert: 0 },
            ],
            w: [-0.3, -0.3, -0.4, 1.0],
            n: Vec3::new(0.0, 1.0, 0.0),
            t: 0.5,
        };
        let impacts = vec![mk(0, 1), mk(2, 3)];
        let zones = build_zones(&sys, &impacts);
        assert_eq!(zones.len(), 2);
        let merged = merge_zones(&zones).unwrap();
        assert_eq!(merged.impacts.len(), 2);
        assert_eq!(merged.entities.len(), 4);
        assert_eq!(merged.n_dofs(), 24);
        for b in 0..4 {
            assert!(merged.entities.contains(&Entity::Rigid(b)));
        }
        assert!(merge_zones(&[]).is_none());
    }
}
