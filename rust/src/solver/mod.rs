//! Numerical solvers: implicit Euler time integration (paper Eq. 3), the
//! per-zone nonlinearly-constrained projection (Eq. 6), and the global
//! LCP-style baseline used by the Table-1 ablation.
pub mod implicit_euler;
pub mod lcp;
pub mod zone_solver;
