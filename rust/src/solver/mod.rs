//! Numerical solvers: implicit Euler time integration
//! ([`implicit_euler`], paper Eq. 3), the per-zone
//! nonlinearly-constrained projection ([`zone_solver`], Eq. 6), and the
//! global LCP-style baseline ([`lcp`]) used by the Table-1 ablation.
//! Zone problems can borrow their state from the cross-scene
//! [`crate::util::arena::BatchArena`]; the solvers themselves draw
//! inner-loop temporaries from [`crate::util::scratch`]. Both reuse
//! layers are bitwise-neutral.
pub mod implicit_euler;
pub mod lcp;
pub mod zone_solver;
