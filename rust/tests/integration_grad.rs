//! End-to-end gradient integration: full episodes, losses on final state,
//! gradients validated against finite differences and used for actual
//! optimization (a miniature of the paper's §7.4 applications).

use diffsim::bodies::{RigidBody, System};
use diffsim::engine::backward::{backward, LossGrad};
use diffsim::engine::{DiffMode, SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};

fn ground() -> RigidBody {
    RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
        .with_position(Vec3::new(0.0, -0.5, 0.0))
}

/// Episode: push a cube along the ground with a constant force for T
/// steps; loss = (x_T − target)². Returns (loss, dL/dforce).
fn rollout(force: f64, target: f64, diff: DiffMode) -> (f64, f64) {
    let mut sys = System::new();
    sys.add_rigid(ground());
    sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.501, 0.0)));
    let mut sim = Simulation::new(
        sys,
        SimConfig { record_tape: true, dt: 1.0 / 100.0, diff_mode: diff, ..Default::default() },
    );
    let steps = 30;
    for _ in 0..steps {
        sim.sys.rigids[1].ext_force = Vec3::new(force, 0.0, 0.0);
        sim.step();
    }
    let x = sim.sys.rigids[1].translation().x;
    let loss = (x - target) * (x - target);
    let mut seed = LossGrad::zeros(&sim);
    seed.rigid_q[1][3] = 2.0 * (x - target);
    let g = backward(&sim, &seed);
    let dldf: f64 = (0..steps).map(|s| g.rigid_force[s][1].x).sum();
    (loss, dldf)
}

#[test]
fn force_gradient_matches_fd_through_resting_contact() {
    let (_, dldf) = rollout(2.0, 1.0, DiffMode::Qr);
    let eps = 1e-4;
    let (lp, _) = rollout(2.0 + eps, 1.0, DiffMode::Qr);
    let (lm, _) = rollout(2.0 - eps, 1.0, DiffMode::Qr);
    let fd = (lp - lm) / (2.0 * eps);
    assert!(
        (dldf - fd).abs() < 2e-2 * (1.0 + fd.abs()),
        "analytic {dldf} vs fd {fd}"
    );
}

#[test]
fn qr_and_dense_modes_agree_end_to_end() {
    let (_, g_qr) = rollout(2.0, 1.0, DiffMode::Qr);
    let (_, g_dense) = rollout(2.0, 1.0, DiffMode::Dense);
    assert!(
        (g_qr - g_dense).abs() < 1e-6 * (1.0 + g_dense.abs()),
        "qr {g_qr} vs dense {g_dense}"
    );
}

#[test]
fn gradient_descent_solves_push_to_target() {
    // The Fig-7-style loop in miniature: optimize the force so the cube
    // reaches the target; gradient descent must converge in a few steps.
    let target = 0.8;
    let mut force = 0.5;
    let mut last_loss = f64::MAX;
    // d²L/df² ≈ 2·(∂x/∂f)² ≈ 0.004 for this horizon → lr ≈ 1/curvature.
    let lr = 200.0;
    for it in 0..30 {
        let (loss, grad) = rollout(force, target, DiffMode::Qr);
        if loss < 1e-6 {
            return; // converged
        }
        force -= lr * grad;
        if it > 2 {
            assert!(loss < last_loss * 1.5, "diverging at iter {it}: {loss} > {last_loss}");
        }
        last_loss = loss;
    }
    assert!(last_loss < 1e-3, "did not converge: final loss {last_loss}");
}

#[test]
fn mass_estimation_gradient_signs() {
    // Fig-9 style: two cubes collide; total momentum after = (m1−m2)·v.
    // dL/dm1 must pull m1 toward the value matching the target momentum.
    let run = |density: f64| -> (Simulation, f64) {
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), density)
                .with_position(Vec3::new(-1.2, 0.0, 0.03))
                .with_velocity(Vec3::new(1.0, 0.0, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(0.0, 0.0, 0.0))
                .with_velocity(Vec3::new(-1.0, 0.0, 0.0)),
        );
        let mut sim = Simulation::new(
            sys,
            SimConfig {
                record_tape: true,
                gravity: Vec3::default(),
                dt: 1.0 / 100.0,
                ..Default::default()
            },
        );
        sim.run(80);
        let p = sim.sys.linear_momentum().x;
        (sim, p)
    };
    let (sim, p) = run(2.0);
    // L = (p − p_target)² with p_target > p ⇒ want m1 larger ⇒ dL/dm1 < 0.
    let p_target = p + 1.0;
    let mut seed = LossGrad::zeros(&sim);
    // p = m1·v1' + m2·v2': ∂L/∂v1' = 2(p−pt)·m1  (+ explicit mass term
    // handled below).
    let d = 2.0 * (p - p_target);
    seed.rigid_v[0][3] = d * sim.sys.rigids[0].mass;
    seed.rigid_v[1][3] = d * sim.sys.rigids[1].mass;
    let g = backward(&sim, &seed);
    let explicit = d * sim.sys.rigids[0].qdot[3]; // ∂p/∂m1 direct term
    let total = g.rigid_mass[0] + explicit;
    assert!(total < 0.0, "dL/dm1 should be negative, got {total}");
}
