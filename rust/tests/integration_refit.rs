//! Incremental collision pipeline: the refit-vs-rebuild test oracle.
//!
//! The persistent cross-step collision cache (BVH refits, cull-cache
//! candidate lists, zone warm starts) is an *accelerator*: with
//! `warm_start_zones` off, trajectories, per-step stats, and rollout
//! gradients must be **bitwise identical** whether the cache is enabled
//! (`incremental_collision: true`, the default) or the pipeline
//! rebuilds every surface from scratch each step. These tests pin that
//! contract on rigid-stack, cloth-over-obstacle, and mixed scenes, plus
//! the warm-start opt-in (tolerance + fewer GN iterations, never
//! bitwise) and cache invalidation on topology changes.

use diffsim::batch::SceneBatch;
use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::engine::backward::LossGrad;
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, unit_box};
use diffsim::obs;
use std::sync::Mutex;

/// Serialize tests that toggle the process-wide obs enable flag.
static ENABLE_LOCK: Mutex<()> = Mutex::new(());

fn enable_lock() -> std::sync::MutexGuard<'static, ()> {
    ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn ground() -> RigidBody {
    RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
        .with_position(Vec3::new(0.0, -0.5, 0.0))
}

/// Ground + two stacked cubes: persistent multi-zone rigid contact.
fn rigid_stack_system() -> System {
    let mut sys = System::new();
    sys.add_rigid(ground());
    sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.6, 0.0)));
    sys.add_rigid(
        RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.05, 1.75, 0.0)),
    );
    sys
}

/// A cloth dropping onto a frozen box: cloth-rigid contact plus large
/// per-node motion (the BVH-degradation path's natural workload).
fn cloth_over_obstacle_system() -> System {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(0.6, 0.3, 0.6)))
            .with_position(Vec3::new(0.0, 0.3, 0.0)),
    );
    let cloth = Cloth::from_grid(
        cloth_grid(5, 5, 1.4, 1.4).translated(Vec3::new(-0.7, 0.9, -0.7)),
        0.2,
        500.0,
        1.0,
        0.5,
    );
    sys.add_cloth(cloth);
    sys
}

/// Ground + falling cube + a draping cloth: rigid-rigid and cloth
/// dynamics in one scene (the integration_batch mixed scene).
fn mixed_system(vx: f64) -> System {
    let mut sys = System::new();
    sys.add_rigid(ground());
    sys.add_rigid(
        RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, 0.8, 0.0))
            .with_velocity(Vec3::new(vx, 0.0, 0.0)),
    );
    let cloth = Cloth::from_grid(
        cloth_grid(4, 4, 1.0, 1.0).translated(Vec3::new(4.0, 0.4, 0.0)),
        0.2,
        500.0,
        1.0,
        0.5,
    );
    sys.add_cloth(cloth);
    sys
}

fn cfg_incremental() -> SimConfig {
    // The default: incremental_collision is on.
    let cfg = SimConfig { dt: 1.0 / 100.0, ..Default::default() };
    assert!(cfg.incremental_collision, "incremental pipeline must be the default");
    cfg
}

fn cfg_rebuild() -> SimConfig {
    SimConfig { incremental_collision: false, ..cfg_incremental() }
}

fn assert_sys_bits_eq(a: &System, b: &System, what: &str) {
    for (i, (ra, rb)) in a.rigids.iter().zip(&b.rigids).enumerate() {
        for k in 0..6 {
            assert_eq!(ra.q[k].to_bits(), rb.q[k].to_bits(), "{what}: rigid {i} q[{k}]");
            assert_eq!(ra.qdot[k].to_bits(), rb.qdot[k].to_bits(), "{what}: rigid {i} qdot[{k}]");
        }
    }
    for (c, (ca, cb)) in a.cloths.iter().zip(&b.cloths).enumerate() {
        for (n, (xa, xb)) in ca.x.iter().zip(&cb.x).enumerate() {
            assert!(
                xa.x.to_bits() == xb.x.to_bits()
                    && xa.y.to_bits() == xb.y.to_bits()
                    && xa.z.to_bits() == xb.z.to_bits(),
                "{what}: cloth {c} node {n} x: {xa:?} vs {xb:?}"
            );
        }
        for (n, (va, vb)) in ca.v.iter().zip(&cb.v).enumerate() {
            assert!(
                va.x.to_bits() == vb.x.to_bits()
                    && va.y.to_bits() == vb.y.to_bits()
                    && va.z.to_bits() == vb.z.to_bits(),
                "{what}: cloth {c} node {n} v"
            );
        }
    }
}

#[test]
fn refit_matches_rebuild_bitwise_on_trajectories() {
    // The tentpole oracle: full trajectories AND per-step StepStats
    // (impact counts, detection stats, zone shapes, GN iterations) are
    // bitwise/equal between the cached pipeline and a pipeline that
    // rebuilds every surface each step.
    let scenes: [(&str, fn() -> System); 3] = [
        ("rigid-stack", rigid_stack_system),
        ("cloth-over-obstacle", cloth_over_obstacle_system),
        ("mixed", || mixed_system(0.4)),
    ];
    for (name, build) in scenes {
        let mut inc = Simulation::new(build(), cfg_incremental());
        let mut cold = Simulation::new(build(), cfg_rebuild());
        for step in 0..80 {
            inc.step();
            cold.step();
            assert_eq!(
                inc.last_stats, cold.last_stats,
                "{name}: StepStats diverged at step {step}"
            );
            assert_sys_bits_eq(&inc.sys, &cold.sys, &format!("{name} step {step}"));
        }
        // The cache did real work: surfaces were refit (not rebuilt)
        // across steps, and broad-phase lists were served from cache.
        let ci = inc.collision_counters();
        let cc = cold.collision_counters();
        assert!(ci.refits > 0, "{name}: no refits on the incremental run: {ci:?}");
        assert!(ci.cull_cache_hits > 0, "{name}: cull cache never hit: {ci:?}");
        assert!(
            ci.rebuilds < cc.rebuilds,
            "{name}: incremental must rebuild less than rebuild-every-step \
             ({} vs {})",
            ci.rebuilds,
            cc.rebuilds
        );
        assert_eq!(cc.cull_cache_hits, 0, "{name}: cache off must never hit");
        assert_eq!(ci.warmstart_hits, 0, "{name}: warm starts default off");
    }
}

#[test]
fn refit_matches_rebuild_bitwise_for_rollout_gradients() {
    // Same oracle through the taped lockstep rollout: losses and
    // end-to-end gradients (initial conditions) must be bitwise
    // identical with the cache on vs off.
    let steps = 10;
    let vxs = [0.0, 0.5];
    let run = |cfg: SimConfig| {
        let mut batch = SceneBatch::from_scene(&mixed_system(0.0), &cfg, vxs.len(), |i, sys| {
            sys.rigids[1] = RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(0.0, 0.52, 0.0))
                .with_velocity(Vec3::new(vxs[i], 0.0, 0.0));
        });
        let res = batch.rollout_grad_lockstep(
            steps,
            |_| (),
            |_, _i, _s, _sim| {},
            |_, sim, _| {
                let mut seed = LossGrad::zeros(sim);
                seed.rigid_q[1][4] = 1.0; // d(loss)/d(cube y)
                seed.cloth_x[0][8].x = 1.0;
                (sim.sys.rigids[1].q[4] + sim.sys.cloths[0].x[8].x, seed)
            },
        );
        let q0: Vec<[f64; 6]> = res.grads.iter().map(|g| g.rigid_q0[1]).collect();
        let v0: Vec<[f64; 6]> = res.grads.iter().map(|g| g.rigid_v0[1]).collect();
        let cx0: Vec<Vec3> = res.grads.iter().map(|g| g.cloth_x0[0][8]).collect();
        (res.losses, q0, v0, cx0)
    };
    let (l_inc, q_inc, v_inc, c_inc) = run(cfg_incremental());
    let (l_cold, q_cold, v_cold, c_cold) = run(cfg_rebuild());
    for i in 0..vxs.len() {
        assert_eq!(l_inc[i].to_bits(), l_cold[i].to_bits(), "scene {i} loss");
        for k in 0..6 {
            assert_eq!(q_inc[i][k].to_bits(), q_cold[i][k].to_bits(), "scene {i} dL/dq0[{k}]");
            assert_eq!(v_inc[i][k].to_bits(), v_cold[i][k].to_bits(), "scene {i} dL/dv0[{k}]");
        }
        assert_eq!(c_inc[i].x.to_bits(), c_cold[i].x.to_bits(), "scene {i} dL/dcloth_x0");
    }
}

#[test]
fn warm_start_stays_in_tolerance_and_reduces_gn_iters() {
    // Warm-starting zone solves from the previous step's parked
    // multipliers is opt-in and NOT bitwise: the contract is (a) the
    // trajectory stays within solver tolerance of the cold run, and
    // (b) persistent contact costs strictly fewer GN iterations.
    let run = |warm: bool| {
        let cfg = SimConfig { warm_start_zones: warm, ..cfg_incremental() };
        let mut sim = Simulation::new(rigid_stack_system(), cfg);
        sim.run(60); // settle into persistent contact
        let mut gn = 0usize;
        for _ in 0..60 {
            sim.step();
            gn += sim.last_stats.gn_iters;
        }
        assert!(sim.last_stats.zones > 0, "stack must stay in contact");
        (sim, gn)
    };
    let (cold, gn_cold) = run(false);
    let (warm, gn_warm) = run(true);
    assert!(
        gn_warm < gn_cold,
        "warm starts must strictly reduce GN iterations in persistent \
         contact: warm {gn_warm} vs cold {gn_cold}"
    );
    for (i, (bw, bc)) in warm.sys.rigids.iter().zip(&cold.sys.rigids).enumerate() {
        for k in 0..6 {
            assert!(
                (bw.q[k] - bc.q[k]).abs() < 1e-5,
                "rigid {i} q[{k}]: warm {} vs cold {}",
                bw.q[k],
                bc.q[k]
            );
        }
    }
    let cw = warm.collision_counters();
    assert!(cw.warmstart_hits > 0, "persistent contact must hit the warm store: {cw:?}");
    // The very first contact step has nothing parked: a key miss falls
    // back to the cold start (counted, not crashed).
    assert!(cw.warmstart_misses > 0, "first contact must miss cold: {cw:?}");
    assert_eq!(cold.collision_counters().warmstart_hits, 0, "opt-out must never warm-start");
}

#[test]
fn topology_change_mid_run_invalidates_cache_and_stays_bitwise() {
    // Adding a body mid-run changes the surface set: the parked cache
    // must be detected stale (CollisionState::matches), dropped, and
    // rebuilt — and the trajectory must still match the
    // rebuild-every-step pipeline bitwise through the change.
    let mut inc = Simulation::new(mixed_system(0.2), cfg_incremental());
    let mut cold = Simulation::new(mixed_system(0.2), cfg_rebuild());
    inc.run(30);
    cold.run(30);
    assert_sys_bits_eq(&inc.sys, &cold.sys, "before topology change");
    let rebuilds_before = inc.collision_counters().rebuilds;
    let dropped =
        || RigidBody::from_mesh(unit_box(), 0.8).with_position(Vec3::new(0.1, 2.0, 0.05));
    inc.sys.add_rigid(dropped());
    cold.sys.add_rigid(dropped());
    inc.step();
    cold.step();
    // Every surface of the grown system was rebuilt from scratch.
    let n_surfs = (inc.sys.rigids.len() + inc.sys.cloths.len()) as u64;
    assert_eq!(
        inc.collision_counters().rebuilds - rebuilds_before,
        n_surfs,
        "stale cache must be dropped and every surface rebuilt"
    );
    inc.run(29);
    cold.run(29);
    assert_sys_bits_eq(&inc.sys, &cold.sys, "after topology change");
    // Explicit invalidation is equivalent to a cold pipeline restart:
    // still bitwise, pipeline rebuilds once.
    let rebuilds_before = inc.collision_counters().rebuilds;
    inc.invalidate_collision_cache();
    inc.step();
    cold.step();
    assert_sys_bits_eq(&inc.sys, &cold.sys, "after explicit invalidation");
    assert_eq!(inc.collision_counters().rebuilds - rebuilds_before, n_surfs);
}

#[test]
fn collision_counters_publish_to_obs_summary() {
    // The collision.* counters drain into the telemetry registry at
    // commit and therefore appear in obs::summary().
    let _l = enable_lock();
    obs::enable();
    let mut sim = Simulation::new(
        rigid_stack_system(),
        SimConfig { warm_start_zones: true, ..cfg_incremental() },
    );
    sim.run(80);
    obs::disable();
    let mine = sim.collision_counters();
    assert!(mine.refits > 0 && mine.warmstart_hits > 0, "run produced no cache work: {mine:?}");
    let j = obs::summary();
    let counters = j.get("counters").expect("summary has a counters section");
    for name in [
        "collision.refits",
        "collision.rebuilds",
        "collision.cull_cache_hits",
        "collision.cull_cache_misses",
        "collision.warmstart_hits",
        "collision.warmstart_misses",
    ] {
        assert!(counters.get(name).is_some(), "summary missing {name}");
        // ≥ 1, not ==: the registry is process-global; this sim's run
        // moved every one of the six at least once.
        assert!(obs::counter(name).get() > 0, "counter {name} never moved");
    }
    // Registry totals at least cover this sim's own contribution.
    assert!(obs::counter("collision.refits").get() >= mine.refits);
    assert!(obs::counter("collision.warmstart_hits").get() >= mine.warmstart_hits);
}

#[test]
fn check_invariants_hook_passes_on_a_live_cache() {
    // The parked BVHs must satisfy the structural invariants after any
    // number of refit/rebuild cycles; the hook is a no-op before the
    // first step and on a cache-off sim.
    let mut sim = Simulation::new(cloth_over_obstacle_system(), cfg_incremental());
    sim.check_collision_cache_invariants(); // nothing parked yet
    for _ in 0..60 {
        sim.step();
        sim.check_collision_cache_invariants();
    }
    let mut off = Simulation::new(cloth_over_obstacle_system(), cfg_rebuild());
    off.run(5);
    off.check_collision_cache_invariants(); // cache off → nothing parked
}
