//! Async pipelined batch stepping (`batch::pipeline`): bitwise parity
//! of the pipelined drivers against the lockstep and sequential paths
//! (trajectories, fig7 losses, fig8 gradient-driven curves), the
//! panic-drain contract, and the bounded in-flight window.

use diffsim::batch::pipeline::BatchPipeline;
use diffsim::batch::SceneBatch;
use diffsim::bodies::{RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::experiments::{control, inverse};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::util::pool::Pool;
use diffsim::util::rng::Pcg32;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Ground + one falling cube; different vx values give the scenes
/// different contact histories (uneven per-scene step cost — the
/// workload shape pipelining targets).
fn drop_system(vx: f64) -> System {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    sys.add_rigid(
        RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, 0.8, 0.0))
            .with_velocity(Vec3::new(vx, 0.0, 0.0)),
    );
    sys
}

fn cfg_1w() -> SimConfig {
    SimConfig { dt: 1.0 / 100.0, workers: 1, ..Default::default() }
}

#[test]
fn pipelined_scene_rollouts_bitwise_match_sequential_and_lockstep() {
    // The same scenes stepped three ways — streamed through the
    // pipeline window, in a blocking lockstep batch, and sequentially —
    // must agree bit for bit.
    let vxs = [0.0, 0.4, -0.3, 1.1];
    let steps = 50;
    let pipe = BatchPipeline::new(4).with_window(2);
    let piped: Vec<Simulation> = pipe.map_windowed(
        vxs.len(),
        |i| {
            let mut sim = Simulation::new(drop_system(vxs[i]), cfg_1w());
            sim.run(steps);
            sim
        },
        |_i, sim| sim,
    );
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: 4, ..Default::default() };
    let mut lock = SceneBatch::from_scene(&drop_system(0.0), &cfg, vxs.len(), |i, sys| {
        sys.rigids[1] = sys.rigids[1]
            .clone()
            .with_position(Vec3::new(0.0, 0.8, 0.0))
            .with_velocity(Vec3::new(vxs[i], 0.0, 0.0));
    });
    lock.run_lockstep(steps);
    for (i, &vx) in vxs.iter().enumerate() {
        let mut solo = Simulation::new(drop_system(vx), cfg_1w());
        solo.run(steps);
        for k in 0..6 {
            assert!(
                piped[i].sys.rigids[1].q[k] == solo.sys.rigids[1].q[k],
                "scene {i} q[{k}]: pipelined {} vs sequential {}",
                piped[i].sys.rigids[1].q[k],
                solo.sys.rigids[1].q[k]
            );
            assert!(
                piped[i].sys.rigids[1].qdot[k] == solo.sys.rigids[1].qdot[k],
                "scene {i} qdot[{k}]: pipelined vs sequential"
            );
            assert!(
                lock.sim(i).sys.rigids[1].q[k] == solo.sys.rigids[1].q[k],
                "scene {i} q[{k}]: lockstep vs sequential"
            );
        }
    }
}

#[test]
fn fig7_losses_pipelined_lockstep_sequential_bitwise() {
    // The acceptance bar: the pipelined fig7 population evaluation
    // produces bitwise-identical losses to the lockstep fallback and to
    // per-candidate sequential evaluation.
    let target = Vec3::new(0.35, 0.0, 0.15);
    let mut rng = Pcg32::new(5);
    let cands: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..2 * inverse::STEPS).map(|_| rng.range(-0.4, 0.4)).collect())
        .collect();
    let pipelined = inverse::loss_only_batch(&cands, target);
    let lockstep = inverse::loss_only_lockstep(&cands, target);
    assert_eq!(pipelined.len(), cands.len());
    for (i, c) in cands.iter().enumerate() {
        let sequential = inverse::loss_only(c, target);
        assert!(
            pipelined[i] == sequential,
            "candidate {i}: pipelined {} vs sequential {sequential}",
            pipelined[i]
        );
        assert!(
            lockstep[i] == sequential,
            "candidate {i}: lockstep {} vs sequential {sequential}",
            lockstep[i]
        );
    }
}

#[test]
fn fig8_curves_pipelined_matches_lockstep_bitwise() {
    // Double-buffered scene construction must not change a bit of the
    // fig8 training trajectory. The curve is a fixpoint of the whole
    // gradient chain (rollout → backward → Adam → next rollout under
    // the updated policy), so exact equality across several updates is
    // only possible if every per-update gradient matched bitwise.
    let pipelined = control::train_ours_sticks_batch(3, 2, 9);
    let blocking = control::train_ours_sticks_lockstep(3, 2, 9);
    assert_eq!(pipelined.len(), blocking.len());
    for (u, (a, b)) in pipelined.iter().zip(&blocking).enumerate() {
        assert!(a == b, "update {u}: pipelined {a} vs lockstep {b}");
    }
}

#[test]
fn panic_in_one_scene_drains_and_rethrows_without_poisoning_the_pool() {
    // One scene's job panics mid-stream: the payload must re-surface at
    // that scene's wait, every other in-flight job must drain before
    // the unwind leaves the pipeline call, and the shared pool must
    // keep serving work afterwards.
    let pipe = BatchPipeline::new(4).with_window(2);
    let completed = AtomicUsize::new(0);
    let r = catch_unwind(AssertUnwindSafe(|| {
        pipe.map_windowed(
            6,
            |i| {
                if i == 2 {
                    panic!("scene 2 diverged");
                }
                let mut sim = Simulation::new(drop_system(0.2 * i as f64), cfg_1w());
                sim.run(10);
                completed.fetch_add(1, Ordering::SeqCst);
                sim.sys.rigids[1].translation().y
            },
            |_i, y| y,
        )
    }));
    let payload = r.expect_err("the scene panic must reach the submitter");
    let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
    assert!(msg.contains("scene 2 diverged"), "payload: {msg}");
    // Drained: nothing is still stepping after the unwind.
    let settled = completed.load(Ordering::SeqCst);
    std::thread::sleep(std::time::Duration::from_millis(30));
    assert_eq!(
        completed.load(Ordering::SeqCst),
        settled,
        "scene jobs outlived the pipeline drain"
    );
    // The pool survives for both maps and fresh pipelines.
    assert_eq!(Pool::shared(4).map(6, |i| i + 1), (1..7).collect::<Vec<_>>());
    let again =
        pipe.map_windowed(3, |i| i * 2, |_i, v| v);
    assert_eq!(again, vec![0, 2, 4]);
}

#[test]
fn in_flight_scenes_never_exceed_the_window() {
    // Budget 8, window 3: the window (not the budget) must be the
    // binding constraint on concurrently-live scenes.
    let live = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let pipe = BatchPipeline::new(8).with_window(3);
    assert_eq!(pipe.window(), 3);
    let out = pipe.map_windowed(
        12,
        |i| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            let mut sim = Simulation::new(drop_system(0.1 * i as f64), cfg_1w());
            sim.run(5);
            live.fetch_sub(1, Ordering::SeqCst);
            i
        },
        |_i, v| v,
    );
    assert_eq!(out, (0..12).collect::<Vec<_>>());
    assert!(
        peak.load(Ordering::SeqCst) <= 3,
        "window 3 exceeded: {} scenes were live at once",
        peak.load(Ordering::SeqCst)
    );
}
