//! Fault containment & recovery: the engine retry ladder, per-scene
//! quarantine in `SceneBatch`, the coordinator dispatch fallback, and
//! the pool's panic-at-wait drain — each driven deterministically by
//! the seeded fault-injection harness (`--features faultinject`) and
//! asserted against the matching `fault.*` obs counters.
//!
//! The unconditional tests (no feature) pin the bitwise-parity
//! contract: with no faults armed, the fault-contained paths commit
//! states bit-identical to the fail-fast paths.

use diffsim::batch::{BatchPipeline, FaultPolicy, SceneBatch};
use diffsim::bodies::{RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::util::rng::Pcg32;

/// With the `faultinject` feature compiled in, the injection plan is
/// process-global, so an armed chaos test could leak faults into the
/// healthy-path tests running on other harness threads. Every test in
/// this binary holds this lock. (CI's chaos job additionally runs the
/// whole workspace with `--test-threads=1` for the same reason.)
static FAULT_SEQ: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn fault_excluded() -> std::sync::MutexGuard<'static, ()> {
    FAULT_SEQ.lock().unwrap_or_else(|e| e.into_inner())
}

fn ground() -> RigidBody {
    RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
        .with_position(Vec3::new(0.0, -0.5, 0.0))
}

fn falling_cube(vx: f64) -> RigidBody {
    RigidBody::from_mesh(unit_box(), 1.0)
        .with_position(Vec3::new(0.0, 0.8, 0.0))
        .with_velocity(Vec3::new(vx, 0.0, 0.0))
}

fn drop_system(vx: f64) -> System {
    let mut sys = System::new();
    sys.add_rigid(ground());
    sys.add_rigid(falling_cube(vx));
    sys
}

fn cfg100() -> SimConfig {
    SimConfig { dt: 1.0 / 100.0, ..Default::default() }
}

/// A single settled scene: the cube is in resting contact, so every
/// subsequent step runs at least one zone solve — which makes
/// site-invocation indices predictable for `arm_at` schedules.
fn settled_sim() -> Simulation {
    let mut sim = Simulation::new(drop_system(0.0), cfg100());
    sim.run(60);
    assert!(sim.last_stats.zones > 0, "settled cube must be in contact");
    sim
}

fn assert_rigid_bits_eq(a: &System, b: &System, what: &str) {
    for (i, (ra, rb)) in a.rigids.iter().zip(&b.rigids).enumerate() {
        for k in 0..6 {
            assert_eq!(ra.q[k].to_bits(), rb.q[k].to_bits(), "{what}: rigid {i} q[{k}]");
            assert_eq!(
                ra.qdot[k].to_bits(),
                rb.qdot[k].to_bits(),
                "{what}: rigid {i} qdot[{k}]"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Unconditional: bitwise parity of the contained paths on healthy scenes
// ---------------------------------------------------------------------

#[test]
fn isolate_policy_is_bitwise_fail_fast_on_healthy_scenes() {
    let _x = fault_excluded();
    let vxs = [0.0, 0.6];
    let build = || {
        SceneBatch::from_scene(&drop_system(0.0), &cfg100(), vxs.len(), |i, sys| {
            sys.rigids[1] = falling_cube(vxs[i]);
        })
    };
    let mut fail_fast = build();
    let mut isolate = build();
    isolate.set_fault_policy(FaultPolicy::Isolate);
    let mut retry = build();
    retry.set_fault_policy(FaultPolicy::Retry);
    fail_fast.run(60);
    isolate.run(60);
    retry.run(60);
    for i in 0..vxs.len() {
        assert!(!isolate.is_quarantined(i), "healthy scene {i} must not quarantine");
        assert_rigid_bits_eq(&isolate.sim(i).sys, &fail_fast.sim(i).sys, "isolate run");
        assert_rigid_bits_eq(&retry.sim(i).sys, &fail_fast.sim(i).sys, "retry run");
    }
    // Same contract on the lockstep path.
    let mut fail_fast = build();
    let mut isolate = build();
    isolate.set_fault_policy(FaultPolicy::Isolate);
    fail_fast.run_lockstep(60);
    isolate.run_lockstep(60);
    for i in 0..vxs.len() {
        assert_rigid_bits_eq(&isolate.sim(i).sys, &fail_fast.sim(i).sys, "isolate lockstep");
    }
}

#[test]
fn scenario_fuzz_isolate_smoke() {
    let _x = fault_excluded();
    // Seeded mini scenario fuzz (satellite): randomized drop/stack
    // configurations must neither panic nor reach a non-finite end
    // state under FaultPolicy::Isolate — and with no faults armed,
    // nothing may be quarantined. Each round now runs twice — under
    // the incremental collision pipeline (the default) and with it
    // off — and the two trajectories must stay bitwise-identical,
    // with the parked BVHs passing their structural invariants after
    // every round.
    struct SceneParams {
        mass: f64,
        x0: Vec3,
        v0: Vec3,
        stacked: Option<f64>, // x offset of an optional second cube
    }
    let mut rng = Pcg32::new(0xfa17);
    for round in 0..4 {
        let n_scenes = 2 + rng.below(3);
        let params: Vec<SceneParams> = (0..n_scenes)
            .map(|_| {
                let vx = rng.range(-1.2, 1.2);
                let y0 = rng.range(0.6, 1.4);
                SceneParams {
                    mass: rng.range(0.5, 2.0),
                    x0: Vec3::new(rng.range(-0.3, 0.3), y0, 0.0),
                    v0: Vec3::new(vx, rng.range(-0.5, 0.0), 0.0),
                    // Half the scenes get a second cube stacked above —
                    // stacks exercise multi-zone passes.
                    stacked: (rng.uniform() < 0.5).then(|| rng.range(-0.2, 0.2)),
                }
            })
            .collect();
        let build = |cfg: &SimConfig| {
            let mut batch = SceneBatch::from_scene(&drop_system(0.0), cfg, n_scenes, |_, sys| {
                sys.rigids[1] = falling_cube(0.0);
            });
            for (sim, p) in batch.sims_mut().iter_mut().zip(&params) {
                sim.sys.rigids[1] = RigidBody::from_mesh(unit_box(), p.mass)
                    .with_position(p.x0)
                    .with_velocity(p.v0);
                if let Some(sx) = p.stacked {
                    sim.sys.add_rigid(
                        RigidBody::from_mesh(unit_box(), 1.0)
                            .with_position(Vec3::new(sx, p.x0.y + 1.1, 0.0)),
                    );
                }
            }
            batch.set_fault_policy(FaultPolicy::Isolate);
            batch
        };
        let inc_cfg = cfg100();
        assert!(inc_cfg.incremental_collision, "incremental pipeline must be the default");
        let mut inc = build(&inc_cfg);
        let mut cold = build(&SimConfig { incremental_collision: false, ..cfg100() });
        inc.run(40);
        cold.run(40);
        for (i, (sim, ref_sim)) in inc.sims().iter().zip(cold.sims()).enumerate() {
            assert!(!inc.is_quarantined(i), "round {round} scene {i} quarantined");
            for (r, b) in sim.sys.rigids.iter().enumerate() {
                for k in 0..6 {
                    assert!(
                        b.q[k].is_finite() && b.qdot[k].is_finite(),
                        "round {round} scene {i} rigid {r} non-finite at dof {k}"
                    );
                }
            }
            assert_rigid_bits_eq(
                &sim.sys,
                &ref_sim.sys,
                &format!("fuzz round {round} scene {i} incremental-vs-rebuild"),
            );
            // The parked cross-step BVHs must satisfy their structural
            // invariants after 40 steps of refits and rebuilds.
            sim.check_collision_cache_invariants();
        }
    }
}

#[test]
fn rollback_mid_rollout_invalidates_cache_and_stays_bitwise() {
    let _x = fault_excluded();
    // A mid-rollout rollback must leave the incremental collision
    // pipeline observably cold: poison one scene's forces so the full
    // retry ladder fails (`step_recovering` restores the checkpoint and
    // drops the parked collision cache), then heal it and keep
    // stepping. The trajectory must match — bitwise — a sim with the
    // cache disabled that went through the identical failure.
    let run = |incremental: bool| {
        let cfg = SimConfig { incremental_collision: incremental, ..cfg100() };
        let mut sim = Simulation::new(drop_system(0.0), cfg);
        sim.run(30); // settled contact: the cache is warm and parked
        let q_before = sim.sys.rigids[1].q;
        sim.sys.rigids[1].ext_force = Vec3::new(f64::NAN, 0.0, 0.0);
        sim.step_recovering().expect_err("ladder cannot fix a poisoned input");
        assert_eq!(sim.sys.rigids[1].q, q_before, "rollback must restore state");
        sim.sys.rigids[1].ext_force = Vec3::default();
        for _ in 0..30 {
            sim.step_recovering().expect("healthy again after clearing the poison");
        }
        sim.check_collision_cache_invariants();
        sim
    };
    let inc = run(true);
    let cold = run(false);
    assert_rigid_bits_eq(&inc.sys, &cold.sys, "post-rollback incremental-vs-rebuild");
    // The failed step's rollback dropped the parked cache, so the next
    // step rebuilt every surface from scratch.
    let c = inc.collision_counters();
    assert!(
        c.rebuilds >= 2 * inc.sys.rigids.len() as u64,
        "expected a post-rollback rebuild on top of the initial build: {c:?}"
    );
    assert!(c.refits > 0 && c.cull_cache_hits > 0, "cache idle after recovery: {c:?}");
}

// ---------------------------------------------------------------------
// Chaos suite: seeded fault injection through every recovery path
// ---------------------------------------------------------------------

#[cfg(feature = "faultinject")]
mod chaos {
    use super::*;
    use diffsim::coordinator::Coordinator;
    use diffsim::engine::SceneError;
    use diffsim::obs;
    use diffsim::runtime::Runtime;
    use diffsim::util::faultinject::{self, site, FaultPlan};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, MutexGuard};

    struct ChaosGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

    impl Drop for ChaosGuard {
        fn drop(&mut self) {
            faultinject::clear();
            obs::disable();
        }
    }

    /// The plan and the obs registry are process-global: chaos tests
    /// take the binary-wide exclusion lock and clean both up on drop
    /// (including on assertion-panic unwinds).
    fn chaos() -> ChaosGuard {
        let g = fault_excluded();
        obs::enable();
        ChaosGuard(g)
    }

    /// Snapshot the `fault.*` counters (they are cumulative across the
    /// process; tests assert deltas).
    fn faults() -> [u64; 7] {
        [
            obs::counter("fault.rollbacks").get(),
            obs::counter("fault.retries").get(),
            obs::counter("fault.mu_boosts").get(),
            obs::counter("fault.substeps").get(),
            obs::counter("fault.recovered").get(),
            obs::counter("fault.giveups").get(),
            obs::counter("fault.injected").get(),
        ]
    }

    fn delta(before: [u64; 7], after: [u64; 7]) -> [u64; 7] {
        let mut d = [0; 7];
        for k in 0..7 {
            d[k] = after[k] - before[k];
        }
        d
    }

    #[test]
    fn retry_ladder_rung1_recovers_a_single_injected_divergence() {
        let _g = chaos();
        let mut sim = settled_sim();
        let steps0 = sim.steps;
        let before = faults();
        let mut plan = FaultPlan::new(1);
        plan.arm_at(site::ZONE_SOLVE, &[0]);
        faultinject::install(plan);
        sim.step_recovering().expect("rung 1 must recover");
        faultinject::clear();
        // [rollbacks, retries, mu_boosts, substeps, recovered, giveups, injected]
        assert_eq!(delta(before, faults()), [1, 1, 1, 0, 1, 0, 1]);
        assert_eq!(sim.steps, steps0 + 1, "boosted re-solve commits one full-dt step");
        assert_eq!(faultinject::fired_count(site::ZONE_SOLVE), 0, "cleared plan reads 0");
    }

    #[test]
    fn retry_ladder_escalates_to_half_dt_substeps() {
        let _g = chaos();
        let mut sim = settled_sim();
        let steps0 = sim.steps;
        let dt0 = sim.cfg.dt;
        let before = faults();
        // Poison the first attempt AND the rung-1 boosted re-solve; the
        // rung-2 substep pair's solves (invocations 2+) run clean.
        let mut plan = FaultPlan::new(2);
        plan.arm_at(site::ZONE_SOLVE, &[0, 1]);
        faultinject::install(plan);
        sim.step_recovering().expect("rung 2 must recover");
        faultinject::clear();
        let d = delta(before, faults());
        assert_eq!(d, [2, 2, 1, 1, 1, 0, 2]);
        assert_eq!(sim.steps, steps0 + 2, "a recovered substep pair advances steps by 2");
        assert_eq!(sim.cfg.dt.to_bits(), dt0.to_bits(), "dt restored after the substeps");
        for k in 0..6 {
            assert!(sim.sys.rigids[1].q[k].is_finite());
        }
    }

    #[test]
    fn ladder_gives_up_and_rolls_back_when_every_retry_is_poisoned() {
        let _g = chaos();
        let mut sim = settled_sim();
        let snapshot = sim.sys.rigids[1].q;
        let steps0 = sim.steps;
        let tape0 = sim.tape.len();
        let before = faults();
        let mut plan = FaultPlan::new(3);
        plan.arm_at(site::ZONE_SOLVE, &[0, 1, 2, 3, 4, 5, 6, 7]);
        faultinject::install(plan);
        let err = sim.step_recovering().expect_err("every rung is poisoned");
        faultinject::clear();
        assert!(
            matches!(err, SceneError::ZoneDivergence { .. }),
            "injected divergence should surface: {err}"
        );
        let d = delta(before, faults());
        assert_eq!(d[5], 1, "exactly one giveup");
        assert_eq!(d[4], 0, "nothing recovered");
        assert!(d[0] >= 2, "initial + rung failures all roll back (got {})", d[0]);
        assert_eq!(sim.steps, steps0, "no step committed");
        assert_eq!(sim.tape.len(), tape0, "no tape record leaked");
        for k in 0..6 {
            assert_eq!(
                sim.sys.rigids[1].q[k].to_bits(),
                snapshot[k].to_bits(),
                "state must be bitwise the pre-step state at q[{k}]"
            );
        }
    }

    #[test]
    fn plain_step_counts_injected_nonconvergence_in_stats() {
        // Satellite: a `converged: false` zone solve is not an error on
        // the unchecked path — it's applied, counted in StepStats and
        // the `solver.zone_nonconverged` obs counter, and warned about.
        let _g = chaos();
        let mut sim = settled_sim();
        let c0 = obs::counter("solver.zone_nonconverged").get();
        let mut plan = FaultPlan::new(4);
        plan.arm_at(site::ZONE_SOLVE, &[0]);
        faultinject::install(plan);
        sim.step();
        faultinject::clear();
        assert!(
            sim.last_stats.zone_nonconverged >= 1,
            "stats must count the non-converged solve"
        );
        assert!(
            obs::counter("solver.zone_nonconverged").get() > c0,
            "obs counter must mirror the stats field"
        );
    }

    #[test]
    fn batch_quarantines_the_injected_scene_and_neighbors_finish() {
        let _g = chaos();
        // workers = 1 → scenes solve sequentially in scene order, so
        // zone-solve invocation 0 after install belongs to scene 0.
        let cfg = SimConfig { workers: 1, ..cfg100() };
        let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, 2, |i, sys| {
            sys.rigids[1] = falling_cube([0.0, 0.5][i]);
        });
        batch.run(60); // settle both scenes into resting contact
        for i in 0..2 {
            assert!(batch.sim(i).last_stats.zones > 0, "scene {i} must be in contact");
        }
        batch.set_fault_policy(FaultPolicy::Isolate);
        let steps0 = [batch.sim(0).steps, batch.sim(1).steps];
        let q0 = batch.sim(0).sys.rigids[1].q;
        let mut plan = FaultPlan::new(5);
        plan.arm_at(site::ZONE_SOLVE, &[0]);
        faultinject::install(plan);
        batch.step();
        faultinject::clear();
        assert!(batch.is_quarantined(0), "poisoned scene must quarantine under Isolate");
        assert!(!batch.is_quarantined(1), "healthy neighbor must not");
        let (idx, rec) = batch.quarantined().next().expect("one quarantine record");
        assert_eq!(idx, 0);
        assert!(matches!(rec.error, SceneError::ZoneDivergence { .. }), "{}", rec.error);
        assert_eq!(rec.step, steps0[0], "quarantined at its last committed step");
        assert_eq!(obs::gauge("batch.quarantined").get(), 1);
        assert_eq!(batch.sim(0).steps, steps0[0], "failed step rolled back");
        assert_eq!(batch.sim(1).steps, steps0[1] + 1, "healthy scene advanced");
        for k in 0..6 {
            assert_eq!(batch.sim(0).sys.rigids[1].q[k].to_bits(), q0[k].to_bits());
        }
        // Quarantined scenes sit out subsequent steps entirely.
        batch.step();
        assert_eq!(batch.sim(0).steps, steps0[0]);
        assert_eq!(batch.sim(1).steps, steps0[1] + 2);
        // Release: the scene rejoins stepping and the gauge drops.
        let rec = batch.clear_quarantine(0).expect("record returned on release");
        assert!(matches!(rec.error, SceneError::ZoneDivergence { .. }));
        assert_eq!(obs::gauge("batch.quarantined").get(), 0);
        batch.step();
        assert_eq!(batch.sim(0).steps, steps0[0] + 1, "released scene steps again");
    }

    #[test]
    fn retry_policy_rides_the_ladder_instead_of_quarantining() {
        let _g = chaos();
        let cfg = SimConfig { workers: 1, ..cfg100() };
        let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, 2, |i, sys| {
            sys.rigids[1] = falling_cube([0.0, 0.5][i]);
        });
        batch.run(60);
        batch.set_fault_policy(FaultPolicy::Retry);
        let before = faults();
        let mut plan = FaultPlan::new(6);
        plan.arm_at(site::ZONE_SOLVE, &[0]);
        faultinject::install(plan);
        batch.step();
        faultinject::clear();
        assert!(!batch.is_quarantined(0), "the ladder recovers a one-shot fault");
        assert!(!batch.is_quarantined(1));
        let d = delta(before, faults());
        assert_eq!(d[4], 1, "one recovery");
        assert_eq!(d[5], 0, "no giveups");
        assert_eq!(obs::gauge("batch.quarantined").get(), 0);
    }

    #[test]
    fn lockstep_isolates_the_injected_scene() {
        let _g = chaos();
        let cfg = SimConfig { workers: 1, ..cfg100() };
        let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, 2, |i, sys| {
            sys.rigids[1] = falling_cube([0.0, 0.5][i]);
        });
        batch.run_lockstep(60);
        batch.set_fault_policy(FaultPolicy::Isolate);
        let steps0 = [batch.sim(0).steps, batch.sim(1).steps];
        // In the lockstep union solve (workers = 1), zones are solved in
        // ascending (scene, zone) order: invocation 0 is scene 0's.
        let mut plan = FaultPlan::new(7);
        plan.arm_at(site::ZONE_SOLVE, &[0]);
        faultinject::install(plan);
        batch.step_lockstep();
        faultinject::clear();
        assert!(batch.is_quarantined(0));
        assert!(!batch.is_quarantined(1));
        assert_eq!(batch.sim(0).steps, steps0[0], "failed scene rolled back");
        assert_eq!(batch.sim(1).steps, steps0[1] + 1, "healthy scene committed");
    }

    #[test]
    fn coordinator_dispatch_fault_degrades_to_native_and_stays_bitwise() {
        let _g = chaos();
        let vxs = [0.0, 0.5];
        let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg100(), vxs.len(), |i, sys| {
            sys.rigids[1] = falling_cube(vxs[i]);
        });
        let coord = Arc::new(Coordinator::new(Arc::new(Runtime::empty())));
        for sim in batch.sims_mut() {
            sim.coordinator = Some(coord.clone());
        }
        assert!(batch.shared_coordinator().is_some());
        let injected0 = obs::counter("fault.injected").get();
        let mut plan = FaultPlan::new(8);
        plan.arm_prob(site::COORD_DISPATCH, 1.0);
        faultinject::install(plan);
        batch.run_lockstep(60);
        let visits = faultinject::visit_count(site::COORD_DISPATCH);
        let fired = faultinject::fired_count(site::COORD_DISPATCH);
        faultinject::clear();
        assert!(visits > 0, "lockstep contact steps must reach the dispatch site");
        assert_eq!(fired, visits, "p = 1.0 fires on every visit");
        assert_eq!(obs::counter("fault.injected").get() - injected0, fired);
        let m = coord.metrics.lock().unwrap();
        assert_eq!(m.zone_solve_pjrt_calls, 0, "bucket layer was down");
        assert!(m.zone_solve_native_fallback > 0, "zones routed native");
        drop(m);
        // Fallback correctness: the native path is the same solver, so
        // trajectories are bitwise the sequential per-scene run.
        for (i, &vx) in vxs.iter().enumerate() {
            let mut solo = Simulation::new(drop_system(vx), cfg100());
            solo.run(60);
            assert_rigid_bits_eq(&batch.sim(i).sys, &solo.sys, "coord-fault fallback");
        }
    }

    #[test]
    fn pool_job_fault_rethrows_at_wait_and_the_pool_survives() {
        let _g = chaos();
        let injected0 = obs::counter("fault.injected").get();
        let mut plan = FaultPlan::new(9);
        plan.arm_at(site::POOL_JOB, &[0]);
        faultinject::install(plan);
        let pipe = BatchPipeline::new(2).with_window(2);
        let r = catch_unwind(AssertUnwindSafe(|| pipe.map_windowed(6, |i| i * 2, |_i, v| v)));
        faultinject::clear();
        let payload = r.expect_err("the injected job panic must rethrow at wait");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("injected fault: pool.job"), "payload: {msg}");
        assert_eq!(obs::counter("fault.injected").get() - injected0, 1);
        // Drained, not poisoned: the same pipeline and pool keep working.
        assert_eq!(pipe.map_windowed(4, |i| i + 1, |_i, v| v), vec![1, 2, 3, 4]);
        assert_eq!(pipe.pool().map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn ccd_fault_is_a_conservative_miss_not_a_crash() {
        let _g = chaos();
        // Drop a cube onto the ground with every CCD root query armed to
        // miss: impacts degrade to the proximity/fail-safe backstops.
        // The contract is containment — no panic, finite states — not
        // trajectory equality.
        let mut plan = FaultPlan::new(10);
        plan.arm_prob(site::CCD, 1.0);
        faultinject::install(plan);
        let mut sim = Simulation::new(drop_system(0.0), cfg100());
        let r = sim.try_run(80);
        let visits = faultinject::visit_count(site::CCD);
        faultinject::clear();
        assert!(r.is_ok(), "CCD misses must not fail the step: {r:?}");
        assert!(visits > 0, "the drop must exercise the CCD site");
        for b in &sim.sys.rigids {
            for k in 0..6 {
                assert!(b.q[k].is_finite() && b.qdot[k].is_finite());
            }
        }
    }
}
