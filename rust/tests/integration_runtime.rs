//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! cross-validate against the native rust implementations.
//! Requires `make artifacts` (skips cleanly if absent).

use diffsim::bodies::{RigidBody, System};
use diffsim::collision::zones::build_zones;
use diffsim::collision::{detect, surfaces_from_system};
use diffsim::coordinator::{Coordinator, ZoneBwItem};
use diffsim::diff::implicit::backward_qr;
use diffsim::engine::backward::{backward, LossGrad};
use diffsim::engine::{DiffMode, SimConfig, Simulation};
use diffsim::math::{euler, Vec3};
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::runtime::Runtime;
use diffsim::solver::zone_solver::ZoneProblem;
use diffsim::util::rng::Pcg32;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping PJRT tests (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn rigid_transform_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let coord = Coordinator::new(rt);
    let mut rng = Pcg32::new(42);
    let n = 300; // spans chunking within the 512 bucket
    let mut qs = Vec::new();
    let mut p0s = Vec::new();
    for _ in 0..n {
        qs.push([
            rng.range(-2.0, 2.0),
            rng.range(-1.3, 1.3),
            rng.range(-2.0, 2.0),
            rng.range(-3.0, 3.0),
            rng.range(-3.0, 3.0),
            rng.range(-3.0, 3.0),
        ]);
        p0s.push([rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)]);
    }
    let (xs, jacs) = coord.rigid_transform_batch(&qs, &p0s).expect("pjrt call");
    for i in 0..n {
        let p0 = Vec3::new(p0s[i][0], p0s[i][1], p0s[i][2]);
        let want_x = euler::transform_point(&qs[i], p0);
        let want_j = euler::jacobian(&qs[i], p0);
        for c in 0..3 {
            assert!(
                (xs[i][c] - want_x[c]).abs() < 1e-4,
                "item {i} x[{c}]: pjrt {} native {}",
                xs[i][c],
                want_x[c]
            );
        }
        for r in 0..3 {
            for c in 0..6 {
                assert!(
                    (jacs[i][r][c] - want_j[r][c]).abs() < 1e-3,
                    "item {i} jac[{r}][{c}]: pjrt {} native {}",
                    jacs[i][r][c],
                    want_j[r][c]
                );
            }
        }
    }
    let m = coord.metrics.lock().unwrap();
    assert!(m.rigid_pjrt_calls >= 1);
    assert_eq!(m.rigid_items, n);
}

fn cube_zone(depth: f64) -> (System, ZoneProblem) {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(5.0, 0.5, 5.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 1.0, 0.0)));
    let mut rigid_q: Vec<[f64; 6]> = sys.rigids.iter().map(|b| b.q).collect();
    rigid_q[1][4] = 0.5 - depth;
    let x1: Vec<Vec<Vec3>> = (0..2)
        .map(|b| {
            let mut tmp = sys.rigids[b].clone();
            tmp.q = rigid_q[b];
            tmp.world_verts()
        })
        .collect();
    let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
    let (impacts, _) = detect(&surfs, 1e-3);
    let zones = build_zones(&sys, &impacts);
    assert_eq!(zones.len(), 1);
    let zp = ZoneProblem::build(&sys, &zones[0], &rigid_q, &[], 1e-3);
    (sys, zp)
}

#[test]
fn zone_backward_artifact_matches_native_qr() {
    let Some(rt) = runtime() else { return };
    let coord = Coordinator::new(rt);
    let (_sys, zp) = cube_zone(0.2);
    let sol = zp.solve();
    assert!(sol.converged);
    let mut rng = Pcg32::new(9);
    let grad_z: Vec<f64> = (0..zp.n).map(|_| rng.normal()).collect();
    let native = backward_qr(&zp, &sol, &grad_z).grad_q;
    let items = vec![ZoneBwItem { problem: &zp, solution: &sol, grad_z: &grad_z }];
    let out = coord.zone_backward_batch(&items);
    assert_eq!(out.len(), 1);
    for (a, b) in out[0].iter().zip(&native) {
        // f32 artifact + CG-vs-direct: commensurate tolerance.
        assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()), "pjrt {a} vs native {b}");
    }
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.zone_items, 1);
    assert!(m.zone_occupancy() > 0.0);
}

#[test]
fn full_backward_pjrt_mode_matches_native() {
    let Some(rt) = runtime() else { return };
    // Cube dropped on the ground, loss = final x translation; gradients
    // via native QR vs the PJRT-batched path must agree.
    let build = || {
        let mut sys = System::new();
        sys.add_rigid(
            RigidBody::frozen_from_mesh(box_mesh(Vec3::new(5.0, 0.5, 5.0)))
                .with_position(Vec3::new(0.0, -0.5, 0.0)),
        );
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(0.0, 0.8, 0.0))
                .with_velocity(Vec3::new(0.5, 0.0, 0.0)),
        );
        let mut sim = Simulation::new(
            sys,
            SimConfig { record_tape: true, dt: 1.0 / 100.0, ..Default::default() },
        );
        sim.run(40);
        sim
    };
    let mut sim_native = build();
    sim_native.cfg.diff_mode = DiffMode::Qr;
    let mut seed = LossGrad::zeros(&sim_native);
    seed.rigid_q[1][3] = 1.0;
    let g_native = backward(&sim_native, &seed);

    let mut sim_pjrt = build();
    sim_pjrt.coordinator = Some(Arc::new(Coordinator::new(rt)));
    sim_pjrt.cfg.diff_mode = DiffMode::Pjrt;
    let g_pjrt = backward(&sim_pjrt, &seed);

    for k in 0..6 {
        let (a, b) = (g_pjrt.rigid_q0[1][k], g_native.rigid_q0[1][k]);
        assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()), "q0[{k}]: pjrt {a} native {b}");
        let (a, b) = (g_pjrt.rigid_v0[1][k], g_native.rigid_v0[1][k]);
        assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()), "v0[{k}]: pjrt {a} native {b}");
    }
    let coord = sim_pjrt.coordinator.as_ref().unwrap();
    let m = coord.metrics.lock().unwrap();
    assert!(m.zone_pjrt_calls + m.zone_native_fallback > 0, "no zone work went through");
}

#[test]
fn cloth_step_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    use diffsim::bodies::Cloth;
    use diffsim::mesh::primitives::cloth_grid;
    use diffsim::solver::implicit_euler::cloth_implicit_step;
    // 8x8 grid matches the exported cloth_step_r8x8 artifact.
    let (nx, nz) = (8, 8);
    let mut cloth = Cloth::from_grid(cloth_grid(nx, nz, 1.0, 1.0), 0.2, 500.0, 2.0, 0.1);
    cloth.pin(0);
    cloth.pin(nz);
    // Perturb so internal forces are nonzero.
    let mut rng = Pcg32::new(4);
    for x in &mut cloth.x {
        *x += Vec3::new(rng.range(-0.01, 0.01), rng.range(-0.01, 0.01), rng.range(-0.01, 0.01));
    }
    let h = 0.01;
    let native = cloth_implicit_step(&cloth, h, Vec3::new(0.0, -9.8, 0.0));

    // Assemble the artifact inputs (see aot.py for the contract).
    let name = format!("cloth_step_r{nx}x{nz}");
    let spec = rt.spec(&name).expect("cloth artifact").clone();
    let nv = cloth.n_nodes();
    let ns = spec.inputs[5][0]; // padded spring count
    let mut xf = vec![0.0f32; nv * 3];
    let mut vf = vec![0.0f32; nv * 3];
    let ext = vec![0.0f32; nv * 3];
    let mut pinned = vec![0.0f32; nv];
    let mut mass = vec![0.0f32; nv];
    for i in 0..nv {
        for c in 0..3 {
            xf[3 * i + c] = cloth.x[i][c] as f32;
            vf[3 * i + c] = cloth.v[i][c] as f32;
        }
        pinned[i] = if cloth.pinned[i] { 1.0 } else { 0.0 };
        mass[i] = cloth.node_mass[i] as f32;
    }
    // Spring order in the artifact: stretch edges then bend pairs, in the
    // python grid_topology order == rust build_topology order (both walk
    // faces in the same sequence).
    let mut rest = vec![0.0f32; ns];
    for (k, l0) in cloth.rest_len.iter().enumerate() {
        rest[k] = *l0 as f32;
    }
    for (k, l0) in cloth.bend_rest.iter().enumerate() {
        rest[cloth.rest_len.len() + k] = *l0 as f32;
    }
    let outs = rt
        .call_f32(
            &name,
            &[
                &xf,
                &vf,
                &ext,
                &pinned,
                &mass,
                &rest,
                &[cloth.k_stretch as f32],
                &[cloth.k_bend as f32],
                &[cloth.damping as f32],
                &[h as f32],
                &[-9.8f32],
            ],
        )
        .expect("cloth artifact call");
    let dv = &outs[0];
    for i in 0..nv {
        for c in 0..3 {
            let a = dv[3 * i + c] as f64;
            let b = native.dv[i][c];
            assert!(
                (a - b).abs() < 5e-4 + 5e-3 * b.abs(),
                "node {i}.{c}: pjrt {a} native {b}"
            );
        }
    }
}
