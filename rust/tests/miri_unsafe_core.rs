//! Miri lane over the pointer-erasure and RAII-reuse core — the code
//! whose correctness rests on `unsafe` (`TaskRef`, `SendPtr`,
//! `erase_job`) or on buffer-recycling invariants (scratch, arena).
//!
//! Run with:
//!
//! ```text
//! MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --test miri_unsafe_core
//! ```
//!
//! Every pool here is a dedicated `Pool::new` (its `PoolRuntime` joins
//! its workers on drop), never `Pool::shared`/`Pool::global`: the
//! process-wide runtime's workers outlive `main`, which Miri reports as
//! a thread leak. Sizes are tiny on purpose — Miri runs each access
//! under full borrow tracking, so the point is to cross every unsafe
//! boundary, not to load it.

use diffsim::batch::BatchPipeline;
use diffsim::util::arena::BatchArena;
use diffsim::util::memory::{MemCategory, MemTracker};
use diffsim::util::pool::Pool;
use diffsim::util::scratch;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

// ------------------------------------------------------------- Pool
// `Pool::map` borrows the closure via `TaskRef` (a transmuted
// `&'static dyn Fn`) and `map_mut` writes results through `SendPtr`
// raw-pointer bases. These tests make Miri walk both paths.

#[test]
fn pool_map_borrowed_task_round_trip() {
    let pool = Pool::new(3);
    let bias = 10usize; // captured by reference through the erased task
    let out = pool.map(7, |i| i * i + bias);
    assert_eq!(out, (0..7).map(|i| i * i + bias).collect::<Vec<_>>());
}

#[test]
fn pool_map_mut_disjoint_writes() {
    let pool = Pool::new(2);
    let mut items: Vec<u64> = (0..9).collect();
    let doubled = pool.map_mut(&mut items, |i, x| {
        *x *= 2;
        *x + i as u64
    });
    assert_eq!(items, (0..9).map(|x| x * 2).collect::<Vec<u64>>());
    assert_eq!(doubled, (0..9).map(|x| 2 * x + x).collect::<Vec<u64>>());
}

#[test]
fn pool_submit_wait_returns_result() {
    let pool = Pool::new(2);
    let h = pool.submit(|| 6 * 7);
    assert_eq!(h.wait(), 42);
}

#[test]
fn pool_submit_drop_blocks_until_job_ran() {
    let pool = Pool::new(2);
    let ran = Arc::new(AtomicUsize::new(0));
    {
        let ran = ran.clone();
        let h = pool.submit(move || {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        drop(h); // must block until the job completed
    }
    assert_eq!(ran.load(Ordering::SeqCst), 1);
}

#[test]
fn pool_nested_maps_share_one_runtime() {
    let pool = Pool::new(2);
    let inner = pool.clone();
    let out = pool.map(3, |i| inner.map(2, |j| i * 10 + j).iter().sum::<usize>());
    assert_eq!(out, vec![1, 21, 41]);
}

// --------------------------------------------------- BatchPipeline
// `map_windowed`/`stream` erase the `'env` lifetime of borrowed work
// closures (`erase_job`) on the promise that `drive_window` drains
// every handle. Miri checks the promise: a dangling borrow in any
// drained job is an instant use-after-free report.

#[test]
fn pipeline_map_windowed_borrowed_closure() {
    let pipe = BatchPipeline::with_pool(Pool::new(2)).with_window(2);
    let weights: Vec<f64> = (0..6).map(|i| 1.0 + i as f64).collect();
    let out = pipe.map_windowed(6, |i| weights[i] * 2.0, |_i, v| v);
    assert_eq!(out, weights.iter().map(|w| w * 2.0).collect::<Vec<_>>());
}

#[test]
fn pipeline_prepare_then_stream() {
    let pipe = BatchPipeline::with_pool(Pool::new(2)).with_window(2);
    let generation = pipe.prepare(5, |i| vec![i as f64; 3]);
    let scale = 0.5f64; // borrowed by the erased work closure
    let out = pipe.stream(
        generation,
        |i, seed| seed.iter().sum::<f64>() * scale + i as f64,
        |_i, v| v,
    );
    let expect: Vec<f64> = (0..5).map(|i| (i as f64) * 3.0 * 0.5 + i as f64).collect();
    assert_eq!(out, expect);
}

#[test]
fn pipeline_generation_dropped_without_stream_drains() {
    let pipe = BatchPipeline::with_pool(Pool::new(2));
    let built = Arc::new(AtomicUsize::new(0));
    {
        let built = built.clone();
        let generation = pipe.prepare(4, move |_i| {
            built.fetch_add(1, Ordering::SeqCst);
        });
        drop(generation); // handle drops block until each build ran
    }
    assert_eq!(built.load(Ordering::SeqCst), 4);
}

#[test]
fn pipeline_generations_double_buffer() {
    let pipe = BatchPipeline::with_pool(Pool::new(2));
    let out = pipe.generations(4, |g| g * 3, |g, state| state + g);
    assert_eq!(out, vec![0, 4, 8, 12]);
}

// ---------------------------------------------------------- scratch
// Thread-local RAII buffers: drop parks the allocation, the next take
// reuses it. Miri verifies the park/reuse hand-off never resurrects a
// stale borrow and always reinitializes contents.

#[test]
fn scratch_f64_reuse_is_reinitialized() {
    {
        let mut a = scratch::f64s(8, 1.0);
        a[3] = 99.0;
    } // parked here
    let b = scratch::f64s(8, 0.0);
    assert_eq!(b.len(), 8);
    assert!(b.iter().all(|&x| x == 0.0), "stale scratch contents leaked");
}

#[test]
fn scratch_f32_refill_and_fill_with() {
    let mut buf = scratch::f32s(4, 2.0);
    buf.refill(6, 0.5);
    assert_eq!(&buf[..], &[0.5; 6]);
    buf.fill_with((0..3).map(|i| i as f32));
    assert_eq!(&buf[..], &[0.0, 1.0, 2.0]);
}

#[test]
fn scratch_mat_checkout_is_zeroed() {
    {
        let mut m = scratch::mat(3, 3);
        m[(1, 2)] = 5.0;
    } // parked here
    let m = scratch::mat(2, 4);
    for i in 0..2 {
        for j in 0..4 {
            assert_eq!(m[(i, j)], 0.0);
        }
    }
}

// ------------------------------------------------------------ arena
// `BatchArena` shelves recycle `Vec` allocations across checkouts with
// byte-charge accounting; Miri checks the raw park/take plumbing and
// the RAII guard's charge/uncharge symmetry.

#[test]
fn arena_vec_checkout_park_reuse() {
    let arena = BatchArena::pooled_with(1 << 20, Arc::new(MemTracker::new()));
    let cat = MemCategory::Solver;
    {
        let mut v = arena.vec::<f64>(8, cat);
        v.extend([1.0, 2.0, 3.0]);
        assert!(arena.tracker().current_cat(cat) > 0);
    } // guard drop: uncharges and parks the allocation
    assert_eq!(arena.tracker().current_cat(cat), 0);
    let v2 = arena.vec::<f64>(4, cat);
    assert!(v2.is_empty(), "reused checkout must come back cleared");
    assert!(v2.capacity() >= 4);
}

#[test]
fn arena_loan_f64_zeroed_round_trip() {
    let arena = BatchArena::pooled_with(1 << 20, Arc::new(MemTracker::new()));
    let cat = MemCategory::Tape;
    let mut v = arena.loan_f64_zeroed(6, cat);
    assert_eq!(v, vec![0.0; 6]);
    v[0] = 7.0;
    arena.retire_f64(v, 6, cat);
    assert_eq!(arena.tracker().current_cat(cat), 0);
    // The retired allocation comes back zeroed on the next loan.
    let v2 = arena.loan_f64_zeroed(6, cat);
    assert_eq!(v2, vec![0.0; 6]);
    arena.retire_f64(v2, 6, cat);
}

#[test]
fn arena_loan_vec_park_vec_uncharged() {
    let arena = BatchArena::pooled_with(1 << 20, Arc::new(MemTracker::new()));
    let mut v: Vec<u32> = arena.loan_vec(5);
    v.extend(0..5u32);
    arena.park_vec(v);
    let v2: Vec<u32> = arena.loan_vec(3);
    assert!(v2.is_empty());
}

#[test]
fn arena_disabled_still_loans() {
    let arena = BatchArena::disabled();
    let v = arena.loan_f64_zeroed(4, MemCategory::Contacts);
    assert_eq!(v, vec![0.0; 4]);
    arena.retire_f64(v, 4, MemCategory::Contacts);
}

// ---------------------------------------------- pool × arena × scratch
// The composite shape the engine actually runs: worker threads using
// thread-local scratch while writing results through `SendPtr`.

#[test]
fn workers_use_scratch_while_writing_through_sendptr() {
    let pool = Pool::new(3);
    let out = pool.map(6, |i| {
        let buf = scratch::f64s(4, i as f64);
        buf.iter().sum::<f64>()
    });
    assert_eq!(out, (0..6).map(|i| 4.0 * i as f64).collect::<Vec<_>>());
}
