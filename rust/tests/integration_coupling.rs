//! Two-way coupling integration (paper §7.3): rigid↔cloth interaction in
//! both directions, the capability "no prior differentiable simulation
//! framework" had.

use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, icosphere};

#[test]
fn trampoline_ball_bounces_back_without_penetrating() {
    // Fig. 6 scenario: ball dropped on a pinned trampoline must deflect
    // it, never pass through, and be pushed back upward.
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(12, 12, 2.0, 2.0).translated(Vec3::new(0.0, 1.0, 0.0)),
        0.3,
        5000.0,
        2.0,
        0.5,
    );
    // Pin the whole boundary ring.
    for i in 0..=12 {
        for k in 0..=12 {
            if i == 0 || i == 12 || k == 0 || k == 12 {
                cloth.pin(i * 13 + k);
            }
        }
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(0.25, 2), 2.0)
            .with_position(Vec3::new(0.0, 1.8, 0.0))
            .with_velocity(Vec3::new(0.0, -2.0, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 250.0, ..Default::default() });
    let mut min_ball_y = f64::MAX;
    let mut max_upward_v: f64 = f64::MIN;
    for _ in 0..600 {
        sim.step();
        let b = &sim.sys.rigids[0];
        min_ball_y = min_ball_y.min(b.translation().y);
        max_upward_v = max_upward_v.max(b.linear_velocity().y);
        // Ball center must never go below the trampoline by more than
        // its radius (i.e., no tunnelling through the sheet).
        assert!(b.translation().y > 0.3, "ball tunnelled: y = {}", b.translation().y);
    }
    // It dipped (cloth deformed) ...
    assert!(min_ball_y < 1.35, "ball never deflected the sheet: {min_ball_y}");
    // ... and was pushed back up by the sheet's elasticity.
    assert!(max_upward_v > 0.1, "no rebound: max v_y = {max_upward_v}");
}

#[test]
fn cloth_lifts_rigid_body() {
    // Fig. 5a scenario in miniature: lifting a cloth's pinned corners
    // upward carries a block sitting on the cloth (cloth → rigid force).
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(10, 10, 1.6, 1.6).translated(Vec3::new(0.0, 0.5, 0.0)),
        0.3,
        4000.0,
        2.0,
        1.0,
    );
    let corners = [0usize, 10, 110, 120];
    for &c in &corners {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(box_mesh(Vec3::splat(0.15)), 0.4)
            .with_position(Vec3::new(0.0, 0.68, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 400.0, ..Default::default() });
    // Let the block settle into the cloth.
    sim.run(200);
    let y_settled = sim.sys.rigids[0].translation().y;
    // Raise the pinned corners slowly (quasi-static lift).
    for _ in 0..800 {
        for &c in &corners {
            sim.sys.cloths[0].x[c].y += 0.0006;
        }
        sim.step();
    }
    let y_end = sim.sys.rigids[0].translation().y;
    assert!(
        y_end > y_settled + 0.2,
        "block was not lifted: {y_settled} -> {y_end}"
    );
    assert!(sim.sys.rigids[0].translation().is_finite());
}

#[test]
fn rigid_body_drags_cloth() {
    // Rigid → cloth force direction: a heavy ball dropped on a free
    // cloth carries the center nodes down with it.
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(10, 10, 2.0, 2.0).translated(Vec3::new(0.0, 1.0, 0.0)),
        0.3,
        2000.0,
        2.0,
        0.5,
    );
    for &c in &[0usize, 10, 110, 120] {
        cloth.pin(c);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(0.2, 2), 5.0).with_position(Vec3::new(0.0, 1.5, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 250.0, ..Default::default() });
    sim.run(400);
    let center = sim.sys.cloths[0].x[60]; // middle node
    assert!(center.y < 0.9, "cloth center not dragged down: {}", center.y);
    // Ball rests in the pocket, above the (sagged) center.
    let ball_y = sim.sys.rigids[0].translation().y;
    assert!(ball_y > center.y, "ball below the cloth it rests on");
    assert!(ball_y < 1.2);
}
