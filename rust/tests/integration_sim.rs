//! End-to-end forward-simulation integration: multi-body scenes settle,
//! conserve what they should, and never interpenetrate.

use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::engine::scene::build_scene_str;
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, icosphere, unit_box};
use diffsim::util::rng::Pcg32;

fn ground() -> RigidBody {
    RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
        .with_position(Vec3::new(0.0, -0.5, 0.0))
}

#[test]
fn many_cubes_settle_without_penetration() {
    let mut sys = System::new();
    sys.add_rigid(ground());
    let mut rng = Pcg32::new(11);
    let n = 16;
    for k in 0..n {
        let (i, j) = (k % 4, k / 4);
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
                2.0 * i as f64 - 3.0 + rng.range(-0.05, 0.05),
                0.8 + 0.3 * (k % 3) as f64,
                2.0 * j as f64 - 3.0 + rng.range(-0.05, 0.05),
            )),
        );
    }
    let mut sim = Simulation::new(sys, SimConfig { workers: 4, ..Default::default() });
    sim.run(250);
    for b in sim.sys.rigids.iter().skip(1) {
        let y = b.translation().y;
        assert!((y - 0.5).abs() < 0.05, "cube did not settle: y = {y}");
        let ymin = b.world_verts().iter().map(|p| p.y).fold(f64::MAX, f64::min);
        assert!(ymin > -0.01, "penetrated ground: ymin = {ymin}");
        assert!(b.linear_velocity().norm() < 0.2);
    }
}

#[test]
fn sphere_rolls_and_stays_on_ground() {
    let mut sys = System::new();
    sys.add_rigid(ground());
    sys.add_rigid(
        RigidBody::from_mesh(icosphere(0.5, 2), 1.0)
            .with_position(Vec3::new(0.0, 0.8, 0.0))
            .with_velocity(Vec3::new(1.0, 0.0, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig::default());
    sim.run(300);
    let b = &sim.sys.rigids[1];
    assert!((b.translation().y - 0.5).abs() < 0.05, "y = {}", b.translation().y);
    assert!(b.translation().x > 0.3, "should have moved along +x");
    assert!(b.translation().is_finite());
}

#[test]
fn cloth_catches_falling_box() {
    // Two-way coupling smoke: a pinned cloth catches a box.
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(
        cloth_grid(10, 10, 2.0, 2.0).translated(Vec3::new(0.0, 1.0, 0.0)),
        0.3,
        3000.0,
        2.0,
        2.0,
    );
    for pin in [0, 10, 110, 120] {
        cloth.pin(pin);
    }
    sys.add_cloth(cloth);
    sys.add_rigid(
        RigidBody::from_mesh(box_mesh(Vec3::splat(0.2)), 0.5)
            .with_position(Vec3::new(0.0, 1.8, 0.0)),
    );
    let mut sim = Simulation::new(sys, SimConfig { dt: 1.0 / 250.0, ..Default::default() });
    sim.run(500);
    let b = &sim.sys.rigids[0];
    // Caught: box rests near/below the cloth plane but never falls through.
    assert!(b.translation().y > 0.2, "box fell through: y = {}", b.translation().y);
    assert!(b.translation().y < 1.2, "box never landed: y = {}", b.translation().y);
    // Cloth sags under the box.
    let cmin = sim.sys.cloths[0].x.iter().map(|p| p.y).fold(f64::MAX, f64::min);
    assert!(cmin < 0.95, "cloth did not deform: min y = {cmin}");
}

#[test]
fn scene_config_runs_end_to_end() {
    let mut sim = build_scene_str(
        r#"{
          "dt": 0.005, "workers": 2,
          "bodies": [
            {"type": "ground"},
            {"type": "box", "pos": [0, 1.0, 0]},
            {"type": "sphere", "radius": 0.3, "pos": [1.5, 1.0, 0], "subdiv": 1},
            {"type": "bunny", "radius": 0.4, "pos": [-1.5, 1.0, 0], "subdiv": 1}
          ]
        }"#,
    )
    .unwrap();
    sim.run(200);
    for b in sim.sys.rigids.iter().skip(1) {
        assert!(b.translation().is_finite());
        assert!(b.translation().y > 0.0, "body below ground: {:?}", b.translation());
        assert!(b.translation().y < 1.5);
    }
}

#[test]
fn step_stats_reflect_contact_sparsity() {
    // Paper §5 premise: zones are localized — separated pairs of touching
    // cubes yield multiple small zones, not one global one.
    let mut sys = System::new();
    sys.add_rigid(ground());
    for k in 0..6 {
        sys.add_rigid(
            RigidBody::from_mesh(unit_box(), 1.0)
                .with_position(Vec3::new(4.0 * k as f64, 0.501, 0.0)),
        );
    }
    let mut sim = Simulation::new(sys, SimConfig::default());
    sim.run(8);
    let st = sim.last_stats;
    assert!(st.zones >= 5, "expected ≥5 independent zones, got {}", st.zones);
    assert!(st.max_zone_dofs <= 12, "zones should stay small: {}", st.max_zone_dofs);
}
