//! SIMD kernel modes: the end-to-end scalar-oracle parity suite.
//!
//! `SimConfig::simd` pins the process-wide kernel mode per scene
//! (re-asserted at every step entry). The contract mirrored from the
//! refit-vs-rebuild oracle:
//!
//! * `Ordered` (lane kernels only where summation order is preserved)
//!   must reproduce the `Scalar` oracle **bitwise** — full 80-step
//!   rigid+cloth trajectories, per-step `StepStats`, and taped rollout
//!   losses/gradients.
//! * `Fast` (reassociated reductions) is ULP-perturbed per kernel;
//!   through contact dynamics that compounds, so full-step results are
//!   held to a loose documented tolerance on dissipative scenes that
//!   settle toward the same rest state, plus finiteness and
//!   contact-activity sanity.
//!
//! The kernel mode is process-global: tests serialize on a file-local
//! mutex and run each configuration to completion before the next is
//! constructed.

use diffsim::batch::SceneBatch;
use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::engine::backward::LossGrad;
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::simd::SimdMode;
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, unit_box};
use std::sync::Mutex;

/// Serialize tests (each sim pins the process-wide kernel mode).
static MODE_LOCK: Mutex<()> = Mutex::new(());

fn mode_lock() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn ground() -> RigidBody {
    RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
        .with_position(Vec3::new(0.0, -0.5, 0.0))
}

/// Ground + falling cube + a draping cloth: rigid-rigid and cloth
/// dynamics in one scene (the integration_refit mixed scene).
fn mixed_system(vx: f64) -> System {
    let mut sys = System::new();
    sys.add_rigid(ground());
    sys.add_rigid(
        RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, 0.8, 0.0))
            .with_velocity(Vec3::new(vx, 0.0, 0.0)),
    );
    let cloth = Cloth::from_grid(
        cloth_grid(4, 4, 1.0, 1.0).translated(Vec3::new(4.0, 0.4, 0.0)),
        0.2,
        500.0,
        1.0,
        0.5,
    );
    sys.add_cloth(cloth);
    sys
}

fn cfg_mode(mode: SimdMode) -> SimConfig {
    SimConfig { dt: 1.0 / 100.0, simd: Some(mode), ..Default::default() }
}

fn assert_sys_bits_eq(a: &System, b: &System, what: &str) {
    for (i, (ra, rb)) in a.rigids.iter().zip(&b.rigids).enumerate() {
        for k in 0..6 {
            assert_eq!(ra.q[k].to_bits(), rb.q[k].to_bits(), "{what}: rigid {i} q[{k}]");
            assert_eq!(ra.qdot[k].to_bits(), rb.qdot[k].to_bits(), "{what}: rigid {i} qdot[{k}]");
        }
    }
    for (c, (ca, cb)) in a.cloths.iter().zip(&b.cloths).enumerate() {
        for (n, (xa, xb)) in ca.x.iter().zip(&cb.x).enumerate() {
            assert!(
                xa.x.to_bits() == xb.x.to_bits()
                    && xa.y.to_bits() == xb.y.to_bits()
                    && xa.z.to_bits() == xb.z.to_bits(),
                "{what}: cloth {c} node {n} x: {xa:?} vs {xb:?}"
            );
        }
        for (n, (va, vb)) in ca.v.iter().zip(&cb.v).enumerate() {
            assert!(
                va.x.to_bits() == vb.x.to_bits()
                    && va.y.to_bits() == vb.y.to_bits()
                    && va.z.to_bits() == vb.z.to_bits(),
                "{what}: cloth {c} node {n} v"
            );
        }
    }
}

#[test]
fn ordered_mode_matches_scalar_bitwise_on_trajectories() {
    // The order-preserving lane path: 80 steps of rigid+cloth contact,
    // coordinates, velocities, and per-step stats all bitwise.
    let _l = mode_lock();
    let mut scalar = Simulation::new(mixed_system(0.4), cfg_mode(SimdMode::Scalar));
    let mut scalar_stats = Vec::new();
    for _ in 0..80 {
        scalar.step();
        scalar_stats.push(scalar.last_stats);
    }
    let mut ordered = Simulation::new(mixed_system(0.4), cfg_mode(SimdMode::Ordered));
    for step in 0..80 {
        ordered.step();
        assert_eq!(ordered.last_stats, scalar_stats[step], "StepStats diverged at step {step}");
    }
    assert_sys_bits_eq(&ordered.sys, &scalar.sys, "ordered vs scalar after 80 steps");
    assert!(
        scalar_stats.iter().any(|s| s.zones > 0),
        "trajectory never hit contact — the parity proved nothing"
    );
}

#[test]
fn fast_mode_stays_within_documented_tolerance_on_trajectories() {
    // Fast reassociates reductions: per-kernel ULP noise compounds
    // through contact events, so the contract on a dissipative scene is
    // settling to the same rest state within a loose tolerance — plus
    // finiteness everywhere and real contact activity on both runs.
    let _l = mode_lock();
    let run = |mode: SimdMode| {
        let mut sim = Simulation::new(mixed_system(0.0), cfg_mode(mode));
        let mut zones = 0usize;
        for _ in 0..80 {
            sim.step();
            zones += sim.last_stats.zones;
        }
        (sim, zones)
    };
    let (scalar, z_scalar) = run(SimdMode::Scalar);
    let (fast, z_fast) = run(SimdMode::Fast);
    assert!(z_scalar > 0 && z_fast > 0, "both runs must exercise contact");
    let tol = 2e-3;
    for (i, (rf, rs)) in fast.sys.rigids.iter().zip(&scalar.sys.rigids).enumerate() {
        for k in 0..6 {
            assert!(rf.q[k].is_finite(), "rigid {i} q[{k}] not finite under Fast");
            assert!(
                (rf.q[k] - rs.q[k]).abs() < tol,
                "rigid {i} q[{k}]: fast {} vs scalar {}",
                rf.q[k],
                rs.q[k]
            );
        }
    }
    for (c, (cf, cs)) in fast.sys.cloths.iter().zip(&scalar.sys.cloths).enumerate() {
        for (n, (xf, xs)) in cf.x.iter().zip(&cs.x).enumerate() {
            assert!(
                xf.x.is_finite() && xf.y.is_finite() && xf.z.is_finite(),
                "cloth {c} node {n} not finite under Fast"
            );
            assert!(
                (xf.x - xs.x).abs() < tol
                    && (xf.y - xs.y).abs() < tol
                    && (xf.z - xs.z).abs() < tol,
                "cloth {c} node {n}: fast {xf:?} vs scalar {xs:?}"
            );
        }
    }
}

/// Taped lockstep rollout under a pinned kernel mode: per-scene losses
/// and end-to-end gradients w.r.t. initial conditions.
fn rollout(mode: SimdMode) -> (Vec<f64>, Vec<[f64; 6]>, Vec<[f64; 6]>, Vec<Vec3>) {
    let steps = 10;
    let vxs = [0.0, 0.5];
    let cfg = cfg_mode(mode);
    let mut batch = SceneBatch::from_scene(&mixed_system(0.0), &cfg, vxs.len(), |i, sys| {
        sys.rigids[1] = RigidBody::from_mesh(unit_box(), 1.0)
            .with_position(Vec3::new(0.0, 0.52, 0.0))
            .with_velocity(Vec3::new(vxs[i], 0.0, 0.0));
    });
    let res = batch.rollout_grad_lockstep(
        steps,
        |_| (),
        |_, _i, _s, _sim| {},
        |_, sim, _| {
            let mut seed = LossGrad::zeros(sim);
            seed.rigid_q[1][4] = 1.0; // d(loss)/d(cube y)
            seed.cloth_x[0][8].x = 1.0;
            (sim.sys.rigids[1].q[4] + sim.sys.cloths[0].x[8].x, seed)
        },
    );
    let q0: Vec<[f64; 6]> = res.grads.iter().map(|g| g.rigid_q0[1]).collect();
    let v0: Vec<[f64; 6]> = res.grads.iter().map(|g| g.rigid_v0[1]).collect();
    let cx0: Vec<Vec3> = res.grads.iter().map(|g| g.cloth_x0[0][8]).collect();
    (res.losses, q0, v0, cx0)
}

#[test]
fn ordered_mode_rollout_gradients_bitwise() {
    let _l = mode_lock();
    let (l_s, q_s, v_s, c_s) = rollout(SimdMode::Scalar);
    let (l_o, q_o, v_o, c_o) = rollout(SimdMode::Ordered);
    for i in 0..l_s.len() {
        assert_eq!(l_s[i].to_bits(), l_o[i].to_bits(), "scene {i} loss");
        for k in 0..6 {
            assert_eq!(q_s[i][k].to_bits(), q_o[i][k].to_bits(), "scene {i} dL/dq0[{k}]");
            assert_eq!(v_s[i][k].to_bits(), v_o[i][k].to_bits(), "scene {i} dL/dv0[{k}]");
        }
        assert_eq!(c_s[i].x.to_bits(), c_o[i].x.to_bits(), "scene {i} dL/dcloth_x0");
    }
}

#[test]
fn fast_mode_rollout_gradients_within_tolerance() {
    // Short (10-step) rollout: Fast's reduction noise stays far from
    // any contact-event flip, so losses and gradients track the oracle
    // to much better than the trajectory tolerance.
    let _l = mode_lock();
    let (l_s, q_s, v_s, c_s) = rollout(SimdMode::Scalar);
    let (l_f, q_f, v_f, c_f) = rollout(SimdMode::Fast);
    for i in 0..l_s.len() {
        assert!(l_f[i].is_finite(), "scene {i} loss not finite under Fast");
        assert!(
            (l_s[i] - l_f[i]).abs() <= 1e-6 * (1.0 + l_s[i].abs()),
            "scene {i} loss: fast {} vs scalar {}",
            l_f[i],
            l_s[i]
        );
        for k in 0..6 {
            assert!(
                (q_s[i][k] - q_f[i][k]).abs() <= 1e-3 * (1.0 + q_s[i][k].abs()),
                "scene {i} dL/dq0[{k}]: fast {} vs scalar {}",
                q_f[i][k],
                q_s[i][k]
            );
            assert!(
                (v_s[i][k] - v_f[i][k]).abs() <= 1e-3 * (1.0 + v_s[i][k].abs()),
                "scene {i} dL/dv0[{k}]: fast {} vs scalar {}",
                v_f[i][k],
                v_s[i][k]
            );
        }
        assert!(
            (c_s[i].x - c_f[i].x).abs() <= 1e-3 * (1.0 + c_s[i].x.abs()),
            "scene {i} dL/dcloth_x0: fast {} vs scalar {}",
            c_f[i].x,
            c_s[i].x
        );
    }
}

#[test]
fn config_none_leaves_mode_untouched() {
    // `simd: None` (the default) must not write the process-global
    // mode: pin a mode, build+step a default-config sim, observe the
    // pin still active.
    let _l = mode_lock();
    let prev = diffsim::math::simd::mode();
    diffsim::math::simd::set_mode(SimdMode::Ordered);
    let mut sim = Simulation::new(
        mixed_system(0.0),
        SimConfig { dt: 1.0 / 100.0, ..Default::default() },
    );
    sim.step();
    assert_eq!(diffsim::math::simd::mode(), SimdMode::Ordered);
    diffsim::math::simd::set_mode(prev);
}
