//! Batched multi-scene simulation: batch-vs-sequential equivalence of
//! trajectories, gradients, the vectorized `rollout_grad` path, and the
//! lockstep forward (`run_lockstep` / `zone_solve_batch` dispatch).

use diffsim::batch::SceneBatch;
use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::coordinator::Coordinator;
use diffsim::engine::backward::{backward, LossGrad};
use diffsim::engine::{DiffMode, SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, unit_box};
use diffsim::runtime::Runtime;
use diffsim::util::arena::BatchArena;
use diffsim::util::memory::{MemCategory, MemTracker};
use diffsim::util::pool::Pool;
use std::sync::Arc;

fn ground() -> RigidBody {
    RigidBody::frozen_from_mesh(box_mesh(Vec3::new(20.0, 0.5, 20.0)))
        .with_position(Vec3::new(0.0, -0.5, 0.0))
}

fn falling_cube(vx: f64) -> RigidBody {
    RigidBody::from_mesh(unit_box(), 1.0)
        .with_position(Vec3::new(0.0, 0.8, 0.0))
        .with_velocity(Vec3::new(vx, 0.0, 0.0))
}

/// Ground + cube (contact-rich) + a small draping cloth off to the side
/// (exercises the cloth solver and cloth-rigid zones too).
fn drop_system(vx: f64) -> System {
    let mut sys = System::new();
    sys.add_rigid(ground());
    sys.add_rigid(falling_cube(vx));
    let cloth = Cloth::from_grid(
        cloth_grid(4, 4, 1.0, 1.0).translated(Vec3::new(4.0, 0.4, 0.0)),
        0.2,
        500.0,
        1.0,
        0.5,
    );
    sys.add_cloth(cloth);
    sys
}

#[test]
fn batch_trajectories_bitwise_match_sequential() {
    let vxs = [0.0, 0.4, -0.3, 1.1];
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: 4, ..Default::default() };
    let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, vxs.len(), |i, sys| {
        sys.rigids[1] = falling_cube(vxs[i]);
    });
    batch.run(60);
    for (i, &vx) in vxs.iter().enumerate() {
        let mut solo =
            Simulation::new(drop_system(vx), SimConfig { dt: 1.0 / 100.0, ..Default::default() });
        solo.run(60);
        let (a, b) = (&batch.sim(i).sys, &solo.sys);
        for k in 0..6 {
            assert!(
                a.rigids[1].q[k] == b.rigids[1].q[k],
                "scene {i} q[{k}]: batch {} vs solo {}",
                a.rigids[1].q[k],
                b.rigids[1].q[k]
            );
            assert!(
                a.rigids[1].qdot[k] == b.rigids[1].qdot[k],
                "scene {i} qdot[{k}]: batch {} vs solo {}",
                a.rigids[1].qdot[k],
                b.rigids[1].qdot[k]
            );
        }
        for (n, (xa, xb)) in a.cloths[0].x.iter().zip(&b.cloths[0].x).enumerate() {
            assert!(
                xa.x == xb.x && xa.y == xb.y && xa.z == xb.z,
                "scene {i} cloth node {n}: batch {xa:?} vs solo {xb:?}"
            );
        }
    }
}

/// Bitwise comparison of one scene's rigid body 1 + cloth 0 against a
/// sequential reference.
fn assert_scene_bitwise(label: &str, i: usize, a: &System, b: &System) {
    for k in 0..6 {
        assert!(
            a.rigids[1].q[k] == b.rigids[1].q[k],
            "{label} scene {i} q[{k}]: {} vs solo {}",
            a.rigids[1].q[k],
            b.rigids[1].q[k]
        );
        assert!(
            a.rigids[1].qdot[k] == b.rigids[1].qdot[k],
            "{label} scene {i} qdot[{k}]: {} vs solo {}",
            a.rigids[1].qdot[k],
            b.rigids[1].qdot[k]
        );
    }
    for (n, (xa, xb)) in a.cloths[0].x.iter().zip(&b.cloths[0].x).enumerate() {
        assert!(
            xa.x == xb.x && xa.y == xb.y && xa.z == xb.z,
            "{label} scene {i} cloth node {n}: {xa:?} vs solo {xb:?}"
        );
    }
}

#[test]
fn lockstep_trajectories_bitwise_match_sequential() {
    // The lockstep forward pools every pass's zone solves across scenes
    // (here: the cross-scene pool map — no coordinator); with the native
    // solver the trajectories must stay bitwise-identical to sequential
    // per-scene run(). Different vx values give the scenes different
    // contact histories, so per-pass zone counts are skewed.
    let vxs = [0.0, 0.4, -0.3, 1.1];
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: 4, ..Default::default() };
    let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, vxs.len(), |i, sys| {
        sys.rigids[1] = falling_cube(vxs[i]);
    });
    batch.run_lockstep(60);
    for (i, &vx) in vxs.iter().enumerate() {
        let mut solo =
            Simulation::new(drop_system(vx), SimConfig { dt: 1.0 / 100.0, ..Default::default() });
        solo.run(60);
        assert_scene_bitwise("lockstep", i, &batch.sim(i).sys, &solo.sys);
    }
}

#[test]
fn lockstep_shared_coordinator_one_dispatch_per_step_pass_level() {
    // With one shared coordinator, every (step, fail-safe pass) level
    // must produce exactly one zone_solve_batch dispatch covering all
    // scenes' zones at that level. The artifact-less Runtime::empty()
    // routes every zone through the native fallback inside the
    // coordinator, so trajectories also stay bitwise-identical to
    // sequential stepping.
    let vxs = [0.0, 0.5, -0.8];
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: 3, record_tape: true, ..Default::default() };
    let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, vxs.len(), |i, sys| {
        sys.rigids[1] = falling_cube(vxs[i]);
    });
    let coord = Arc::new(Coordinator::new(Arc::new(Runtime::empty())));
    assert!(batch.shared_coordinator().is_none());
    for sim in batch.sims_mut() {
        sim.coordinator = Some(coord.clone());
    }
    assert!(batch.shared_coordinator().is_some(), "all scenes share one Arc");
    let steps = 40;
    batch.run_lockstep(steps);
    // Parity against sequential per-scene stepping (same record_tape
    // config; the coordinator's forward fallback is the native solver).
    for (i, &vx) in vxs.iter().enumerate() {
        let mut solo = Simulation::new(
            drop_system(vx),
            SimConfig { dt: 1.0 / 100.0, record_tape: true, ..Default::default() },
        );
        solo.run(steps);
        assert_scene_bitwise("coord-lockstep", i, &batch.sim(i).sys, &solo.sys);
    }
    // Expected dispatches: one per (step, pass) level where ANY scene
    // resolved zones — recoverable from the recorded tapes.
    let mut expected = 0usize;
    let mut total_zones = 0usize;
    for s in 0..steps {
        let mut passes: Vec<usize> = Vec::new();
        for i in 0..batch.len() {
            for zr in &batch.sim(i).tape[s].zones {
                if !passes.contains(&zr.pass) {
                    passes.push(zr.pass);
                }
                total_zones += 1;
            }
        }
        expected += passes.len();
    }
    assert!(total_zones > 0, "scene must have contact for this test to bite");
    let m = coord.metrics.lock().unwrap();
    assert_eq!(
        m.zone_solve_dispatches, expected,
        "one zone_solve_batch dispatch per (step, pass) level across all scenes"
    );
    // Artifact-less runtime: everything fell back native, nothing hit PJRT.
    assert_eq!(m.zone_solve_pjrt_calls, 0);
    assert_eq!(m.zone_solve_native_fallback, total_zones);
}

#[test]
fn persistent_pool_lockstep_bitwise_matches_spawn_per_call_and_sequential() {
    // The persistent worker runtime must not change a single bit of any
    // trajectory: run the same lockstep batch on (a) the shared
    // persistent pool, (b) the old spawn-per-call scoped baseline, and
    // compare both against sequential per-scene stepping.
    let vxs = [0.0, 0.6, -0.9];
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: Pool::machine_workers(), ..Default::default() };
    let build = || {
        SceneBatch::from_scene(&drop_system(0.0), &cfg, vxs.len(), |i, sys| {
            sys.rigids[1] = falling_cube(vxs[i]);
        })
    };
    let mut persistent = build();
    persistent.set_pool(Pool::shared(cfg.workers));
    persistent.run_lockstep(50);
    let mut scoped = build();
    scoped.set_pool(Pool::scoped(cfg.workers));
    scoped.run_lockstep(50);
    for (i, &vx) in vxs.iter().enumerate() {
        let mut solo =
            Simulation::new(drop_system(vx), SimConfig { dt: 1.0 / 100.0, ..Default::default() });
        solo.run(50);
        assert_scene_bitwise("persistent-pool", i, &persistent.sim(i).sys, &solo.sys);
        assert_scene_bitwise("scoped-baseline", i, &scoped.sim(i).sys, &solo.sys);
    }
}

/// The Fig-7-style taped cloth scene: 4x4 cloth pinned at two corners,
/// per-step force θ on the center node, loss = center node's final x.
fn cloth_pull_system() -> System {
    let mut sys = System::new();
    let mut cloth = Cloth::from_grid(cloth_grid(3, 3, 1.0, 1.0), 0.3, 100.0, 1.0, 0.2);
    cloth.pin(0);
    cloth.pin(12);
    sys.add_cloth(cloth);
    sys
}

fn cloth_cfg() -> SimConfig {
    SimConfig {
        record_tape: true,
        gravity: Vec3::new(0.0, -2.0, 0.0),
        dt: 1.0 / 100.0,
        ..Default::default()
    }
}

/// Sequential taped episode with force scale `theta`; returns (loss,
/// per-θ gradient via the tape).
fn cloth_episode_sequential(theta: f64, steps: usize) -> (f64, f64) {
    let mut sim = Simulation::new(cloth_pull_system(), cloth_cfg());
    for _ in 0..steps {
        sim.sys.cloths[0].ext_force[8] = Vec3::new(theta, 0.0, 0.0);
        sim.step();
    }
    let loss = sim.sys.cloths[0].x[8].x;
    let mut seed = LossGrad::zeros(&sim);
    seed.cloth_x[0][8].x = 1.0;
    let g = backward(&sim, &seed);
    let dtheta: f64 = (0..steps).map(|s| g.cloth_force[s][0][8].x).sum();
    (loss, dtheta)
}

#[test]
fn rollout_grad_matches_sequential_gradients_and_fd() {
    let steps = 8;
    let thetas = [0.2, 0.5, -0.3, 0.8];
    let mut cfg = cloth_cfg();
    cfg.workers = 4;
    let mut batch = SceneBatch::from_scene(&cloth_pull_system(), &cfg, thetas.len(), |_, _| {});
    let res = batch.rollout_grad(
        steps,
        |_| (),
        |_, i, _s, sim| {
            sim.sys.cloths[0].ext_force[8] = Vec3::new(thetas[i], 0.0, 0.0);
        },
        |_, sim, _| {
            let mut seed = LossGrad::zeros(sim);
            seed.cloth_x[0][8].x = 1.0;
            (sim.sys.cloths[0].x[8].x, seed)
        },
    );
    // Contiguous scene-major gradient buffer, as fed to ml::adam.
    let flat = res.gather_param_grads(1, |_i, g, out| {
        out[0] = (0..steps).map(|s| g.cloth_force[s][0][8].x).sum();
    });
    for (i, &theta) in thetas.iter().enumerate() {
        // (a) batch == sequential single-scene gradients (acceptance:
        // 1e-9; in practice the code path is identical → bitwise).
        let (loss_seq, dtheta_seq) = cloth_episode_sequential(theta, steps);
        assert!(
            (res.losses[i] - loss_seq).abs() <= 1e-12,
            "scene {i}: batch loss {} vs sequential {}",
            res.losses[i],
            loss_seq
        );
        assert!(
            (flat[i] - dtheta_seq).abs() <= 1e-9 * (1.0 + dtheta_seq.abs()),
            "scene {i}: batch grad {} vs sequential {}",
            flat[i],
            dtheta_seq
        );
        // (b) per-scene finite differences on the taped dynamics.
        let eps = 1e-5;
        let (lp, _) = cloth_episode_sequential(theta + eps, steps);
        let (lm, _) = cloth_episode_sequential(theta - eps, steps);
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (flat[i] - fd).abs() < 2e-2 * (1.0 + fd.abs()),
            "scene {i}: analytic {} vs fd {fd}",
            flat[i]
        );
    }
    // Full per-scene Grads match too (initial-condition gradients).
    for (i, &theta) in thetas.iter().enumerate() {
        let mut sim = Simulation::new(cloth_pull_system(), cloth_cfg());
        for _ in 0..steps {
            sim.sys.cloths[0].ext_force[8] = Vec3::new(theta, 0.0, 0.0);
            sim.step();
        }
        let mut seed = LossGrad::zeros(&sim);
        seed.cloth_x[0][8].x = 1.0;
        let g = backward(&sim, &seed);
        for (n, (a, b)) in res.grads[i].cloth_x0[0].iter().zip(&g.cloth_x0[0]).enumerate() {
            assert!(
                (a.x - b.x).abs() <= 1e-9
                    && (a.y - b.y).abs() <= 1e-9
                    && (a.z - b.z).abs() <= 1e-9,
                "scene {i} node {n}: batch {a:?} vs sequential {b:?}"
            );
        }
    }
}

#[test]
fn pjrt_mode_without_coordinator_falls_back_to_qr() {
    // Satellite of the pjrt feature gate: DiffMode::Pjrt with no
    // coordinator (feature or artifacts absent) must produce the QR
    // gradients instead of panicking.
    let run = |mode: DiffMode| -> diffsim::diff::tape::Grads {
        let mut sys = System::new();
        sys.add_rigid(ground());
        sys.add_rigid(falling_cube(0.5));
        let mut sim = Simulation::new(
            sys,
            SimConfig {
                record_tape: true,
                dt: 1.0 / 100.0,
                diff_mode: mode,
                ..Default::default()
            },
        );
        sim.run(40);
        let mut seed = LossGrad::zeros(&sim);
        seed.rigid_q[1][3] = 1.0;
        backward(&sim, &seed)
    };
    let g_qr = run(DiffMode::Qr);
    let g_pjrt = run(DiffMode::Pjrt);
    for k in 0..6 {
        assert!(
            g_qr.rigid_q0[1][k] == g_pjrt.rigid_q0[1][k],
            "q0[{k}]: qr {} vs pjrt-fallback {}",
            g_qr.rigid_q0[1][k],
            g_pjrt.rigid_q0[1][k]
        );
        assert!(
            g_qr.rigid_v0[1][k] == g_pjrt.rigid_v0[1][k],
            "v0[{k}]: qr {} vs pjrt-fallback {}",
            g_qr.rigid_v0[1][k],
            g_pjrt.rigid_v0[1][k]
        );
    }
}

#[test]
fn stateful_rollout_threads_per_scene_state() {
    // rollout() returns the controller state each scene accumulated.
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: 2, ..Default::default() };
    let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, 3, |i, sys| {
        sys.rigids[1] = falling_cube(0.2 * i as f64);
    });
    let states = batch.rollout(
        10,
        |i| vec![i as f64],
        |st: &mut Vec<f64>, _i, _s, sim| {
            st.push(sim.sys.rigids[1].translation().y);
        },
    );
    assert_eq!(states.len(), 3);
    for (i, st) in states.iter().enumerate() {
        assert_eq!(st.len(), 11, "scene {i}: init + one entry per step");
        assert_eq!(st[0], i as f64);
        // The cube falls: observed heights decrease.
        assert!(st[1] > *st.last().unwrap(), "scene {i}: {st:?}");
    }
}

// ---------------------------------------------------------------- arena

#[test]
fn arena_pooling_is_bitwise_neutral_for_lockstep_trajectories() {
    // Same lockstep batch with the default shared arena, with pooling
    // disabled, and with one private arena per scene: every mode must
    // produce bit-identical trajectories (pooled buffers are cleared or
    // zero-filled before use, so contents never depend on history).
    let vxs = [0.0, 0.4, -0.3, 1.1];
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: 4, ..Default::default() };
    let build = || {
        SceneBatch::from_scene(&drop_system(0.0), &cfg, vxs.len(), |i, sys| {
            sys.rigids[1] = falling_cube(vxs[i]);
        })
    };
    let mut shared = build(); // SceneBatch default: one pooled arena
    assert!(shared.arena().is_pooling());
    shared.run_lockstep(60);
    let mut off = build();
    off.set_arena(BatchArena::disabled());
    off.run_lockstep(60);
    let mut per_scene = build();
    for sim in per_scene.sims_mut() {
        sim.set_arena(BatchArena::pooled_with(64 << 20, Arc::new(MemTracker::new())));
    }
    per_scene.run_lockstep(60);
    for i in 0..vxs.len() {
        assert_scene_bitwise("arena-off", i, &off.sim(i).sys, &shared.sim(i).sys);
        assert_scene_bitwise("arena-per-scene", i, &per_scene.sim(i).sys, &shared.sim(i).sys);
    }
}

#[test]
fn arena_pooling_is_bitwise_neutral_for_rollout_gradients() {
    // rollout_grad_lockstep with the arena on vs off: losses, flattened
    // parameter gradients, and initial-condition gradients must be
    // bitwise-identical (the acceptance bar for the pooled tape/solver
    // buffers).
    let steps = 8;
    let thetas = [0.2, 0.5, -0.3, 0.8];
    let run = |arena: Option<BatchArena>| {
        let mut cfg = cloth_cfg();
        cfg.workers = 4;
        let mut batch =
            SceneBatch::from_scene(&cloth_pull_system(), &cfg, thetas.len(), |_, _| {});
        if let Some(a) = arena {
            batch.set_arena(a);
        }
        let res = batch.rollout_grad_lockstep(
            steps,
            |_| (),
            |_, i, _s, sim| {
                sim.sys.cloths[0].ext_force[8] = Vec3::new(thetas[i], 0.0, 0.0);
            },
            |_, sim, _| {
                let mut seed = LossGrad::zeros(sim);
                seed.cloth_x[0][8].x = 1.0;
                (sim.sys.cloths[0].x[8].x, seed)
            },
        );
        let flat = res.gather_param_grads(1, |_i, g, out| {
            out[0] = (0..steps).map(|s| g.cloth_force[s][0][8].x).sum();
        });
        let x0: Vec<Vec3> = res.grads.iter().map(|g| g.cloth_x0[0][8]).collect();
        (res.losses, flat, x0)
    };
    let (losses_on, flat_on, x0_on) = run(None); // default pooled arena
    let (losses_off, flat_off, x0_off) = run(Some(BatchArena::disabled()));
    for i in 0..thetas.len() {
        assert!(
            losses_on[i] == losses_off[i],
            "scene {i} loss: pooled {} vs plain {}",
            losses_on[i],
            losses_off[i]
        );
        assert!(
            flat_on[i] == flat_off[i],
            "scene {i} dL/dθ: pooled {} vs plain {}",
            flat_on[i],
            flat_off[i]
        );
        assert!(
            x0_on[i].x == x0_off[i].x && x0_on[i].y == x0_off[i].y && x0_on[i].z == x0_off[i].z,
            "scene {i} dL/dx0: pooled {:?} vs plain {:?}",
            x0_on[i],
            x0_off[i]
        );
    }
}

#[test]
fn arena_reuse_kicks_in_after_warmup_4x64() {
    // The acceptance config: a 4-scene, 64-step lockstep batch must show
    // a nonzero arena hit rate once warm, with contact and solver
    // traffic visible in the injected tracker's categories.
    let tracker = Arc::new(MemTracker::new());
    let arena = BatchArena::pooled_with(64 << 20, tracker.clone());
    let cfg = SimConfig { dt: 1.0 / 100.0, workers: 4, ..Default::default() };
    let mut batch = SceneBatch::from_scene(&drop_system(0.0), &cfg, 4, |i, sys| {
        sys.rigids[1] = falling_cube(0.3 * i as f64);
    });
    batch.set_arena(arena.clone());
    batch.run_lockstep(64);
    let s = arena.stats();
    assert!(s.takes > 0, "arena saw no traffic: {s:?}");
    assert!(s.hits > 0, "no reuse after 64 warm steps: {s:?}");
    assert!(s.hit_rate() > 0.0);
    assert!(s.retained_bytes > 0, "warm arena retains buffers: {s:?}");
    assert!(tracker.peak_cat(MemCategory::Contacts) > 0, "contact buffers uncounted");
    assert!(tracker.peak_cat(MemCategory::Solver) > 0, "solver buffers uncounted");
    assert_eq!(tracker.current_cat(MemCategory::Tape), 0, "untaped run");
}

#[test]
fn cloth_tape_csr_buffers_recycle_through_the_arena() {
    // PR-4 roadmap follow-up: ClothSolveRec's CSR buffers (system +
    // Jacobian), dfdv, and dv are loaned from the arena at taping time
    // and handed back by StepRecord::recycle at clear_tape. The scene
    // here is cloth-only (no rigid contacts → no zone traffic), so the
    // hit-rate growth across rollouts isolates the cloth recycling
    // path. The first rollout mostly misses (its tape retains every
    // loan); the second starts by clearing those tapes, so its loans
    // must hit the recycled buffers.
    let tracker = Arc::new(MemTracker::new());
    let arena = BatchArena::pooled_with(64 << 20, tracker);
    let mut cfg = cloth_cfg();
    cfg.workers = 2;
    let mut batch = SceneBatch::from_scene(&cloth_pull_system(), &cfg, 2, |_, _| {});
    batch.set_arena(arena.clone());
    let mut rollout = |batch: &mut SceneBatch| {
        batch.rollout_grad_lockstep(
            6,
            |_| (),
            |_, _i, _s, sim| {
                sim.sys.cloths[0].ext_force[8] = Vec3::new(0.3, 0.0, 0.0);
            },
            |_, sim, _| {
                let mut seed = LossGrad::zeros(sim);
                seed.cloth_x[0][8].x = 1.0;
                (sim.sys.cloths[0].x[8].x, seed)
            },
        )
    };
    let r1 = rollout(&mut batch);
    let s1 = arena.stats();
    assert!(s1.takes > 0, "taped cloth solves must loan from the arena: {s1:?}");
    let r2 = rollout(&mut batch); // clears rollout 1's tapes → recycles
    let s2 = arena.stats();
    assert!(
        s2.hits > s1.hits,
        "recycled cloth CSR buffers produced no new hits: {s1:?} -> {s2:?}"
    );
    assert!(s2.hit_rate() > 0.0);
    // Rollout 2 continues from rollout 1's end state; recycling must
    // never corrupt it (bitwise neutrality itself is asserted by
    // `arena_pooling_is_bitwise_neutral_for_rollout_gradients`).
    for l in r1.losses.iter().chain(&r2.losses) {
        assert!(l.is_finite(), "loss went non-finite: {l}");
    }
}

#[test]
fn batch_tapes_register_tape_bytes_and_release_on_clear() {
    // The MemTracker-registration bugfix: batched taped rollouts must
    // report their tape bytes under MemCategory::Tape (previously batch
    // scenes never registered them), and clear_tape must release them.
    let tracker = Arc::new(MemTracker::new());
    let arena = BatchArena::pooled_with(64 << 20, tracker.clone());
    let mut cfg = cloth_cfg();
    cfg.workers = 2;
    let mut batch = SceneBatch::from_scene(&cloth_pull_system(), &cfg, 3, |_, _| {});
    batch.set_arena(arena);
    let res = batch.rollout_grad_lockstep(
        6,
        |_| (),
        |_, _i, _s, sim| {
            sim.sys.cloths[0].ext_force[8] = Vec3::new(0.4, 0.0, 0.0);
        },
        |_, sim, _| {
            let mut seed = LossGrad::zeros(sim);
            seed.cloth_x[0][8].x = 1.0;
            (sim.sys.cloths[0].x[8].x, seed)
        },
    );
    assert_eq!(res.losses.len(), 3);
    let expected: usize = batch.sims().iter().map(|s| s.tape_bytes()).sum();
    assert!(expected > 0, "taped rollout retains records");
    assert_eq!(
        tracker.current_cat(MemCategory::Tape),
        expected,
        "every batch scene's tape bytes are registered"
    );
    for sim in batch.sims_mut() {
        sim.clear_tape();
    }
    assert_eq!(tracker.current_cat(MemCategory::Tape), 0, "clear_tape releases the bytes");
    assert!(tracker.peak_cat(MemCategory::Tape) >= expected);
}
