//! SIMD kernels vs the scalar oracle: the bitwise/ULP parity suite.
//!
//! Every lane kernel in `math/simd.rs` is checked against its
//! always-compiled scalar oracle over seeded dimension sweeps that
//! cover full lane groups, remainder lanes (`n % 4 != 0`), and the
//! `n = 0/1` edges, plus NaN/∞ propagation:
//!
//! * **Elementwise kernels** (axpy, xpby, mul_into, sub_into, Aᵀx
//!   rows): asserted **bitwise** — each element sees the identical
//!   mul/add, so any diff is a kernel bug, not rounding.
//! * **Reduction kernels** (dot, norm, dense/CSR row products): in
//!   `Fast` mode the lane tree reassociates, so agreement is held to
//!   the documented bound `|scalar − fast| ≤ 2·n·ε·Σ|pᵢ|`, and on
//!   well-conditioned (same-sign) data additionally to a small ULP
//!   count via `simd::ulp_diff`. `Ordered` mode is asserted bitwise.
//!
//! Tests that flip the process-global mode serialize on a file-local
//! mutex and restore the previous mode on drop (the pattern
//! `integration_refit.rs` uses for the obs enable flag); per-kernel
//! tests call the explicit `_scalar`/`_fast`/`_lanes` variants and
//! never touch the global.

use diffsim::math::dense::Mat;
use diffsim::math::simd::{self, SimdMode};
use diffsim::math::sparse::Triplets;
use diffsim::util::quick::quick;
use std::sync::Mutex;

/// Serialize tests that set the process-wide kernel mode.
static MODE_LOCK: Mutex<()> = Mutex::new(());

fn mode_lock() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII mode switch: restores the previously active mode on drop.
struct ModeGuard(SimdMode);

impl ModeGuard {
    fn set(m: SimdMode) -> ModeGuard {
        let prev = simd::mode();
        simd::set_mode(m);
        ModeGuard(prev)
    }
}

impl Drop for ModeGuard {
    fn drop(&mut self) {
        simd::set_mode(self.0);
    }
}

/// The documented fast-reduction bound: 2·n·ε·Σ|aᵢ·bᵢ|.
fn dot_bound(a: &[f64], b: &[f64]) -> f64 {
    let sum_abs: f64 = a.iter().zip(b).map(|(x, y)| (x * y).abs()).sum();
    2.0 * a.len() as f64 * f64::EPSILON * sum_abs
}

/// Sweep sizes hitting every remainder class plus the 0/1 edges.
fn sweep_len(g: &mut diffsim::util::quick::Gen) -> usize {
    *g.pick(&[0, 1, 2, 3, 4, 5, 7, 8, 9, 12, 15, 16, 17, 31, 33, 64, 67])
}

#[test]
fn dot_fast_within_documented_bound() {
    quick("simd-dot-bound", 200, |g| {
        let n = sweep_len(g);
        let a = g.vec_normal(n);
        let b = g.vec_normal(n);
        let s = simd::dot_scalar(&a, &b);
        let f = simd::dot_fast(&a, &b);
        let bound = dot_bound(&a, &b);
        assert!((s - f).abs() <= bound, "n={n}: scalar {s} fast {f} bound {bound}");
    });
}

#[test]
fn dot_fast_is_bitwise_below_one_lane() {
    // n < 4 never enters the lane loop: the remainder fold IS the
    // scalar loop, so sub-lane sizes must agree bitwise.
    quick("simd-dot-sublane", 100, |g| {
        let n = g.usize(0, 3);
        let a = g.vec_normal(n);
        let b = g.vec_normal(n);
        assert_eq!(simd::dot_fast(&a, &b).to_bits(), simd::dot_scalar(&a, &b).to_bits());
    });
}

#[test]
fn dot_fast_ulp_small_on_same_sign_data() {
    // With all products positive there is no cancellation: the
    // relative error of either summation order is ≤ n·ε, so the two
    // disagree by only a handful of ULPs — the `ulp_diff` assert the
    // issue calls for.
    quick("simd-dot-ulp", 100, |g| {
        let n = sweep_len(g).max(1);
        let a = g.vec_f64(n, 0.1, 2.0);
        let b = g.vec_f64(n, 0.1, 2.0);
        let s = simd::dot_scalar(&a, &b);
        let f = simd::dot_fast(&a, &b);
        let ulps = simd::ulp_diff(s, f);
        assert!(ulps <= 4 * n as u64 + 4, "n={n}: {ulps} ulps between {s} and {f}");
    });
}

#[test]
fn csr_row_dot_fast_within_documented_bound() {
    quick("simd-csr-row", 200, |g| {
        let n = sweep_len(g);
        let xlen = n + g.usize(1, 8);
        let vals = g.vec_normal(n);
        let x = g.vec_normal(xlen);
        // Random (possibly repeating) gather pattern.
        let cols: Vec<u32> = (0..n).map(|_| g.usize(0, xlen - 1) as u32).collect();
        let s = simd::csr_row_dot_scalar(&vals, &cols, &x);
        let f = simd::csr_row_dot_fast(&vals, &cols, &x);
        let gathered: Vec<f64> = cols.iter().map(|&c| x[c as usize]).collect();
        let bound = dot_bound(&vals, &gathered);
        assert!((s - f).abs() <= bound, "n={n}: scalar {s} fast {f} bound {bound}");
    });
}

#[test]
fn axpy_lanes_bitwise() {
    quick("simd-axpy", 200, |g| {
        let n = sweep_len(g);
        let alpha = g.f64(-3.0, 3.0);
        let x = g.vec_normal(n);
        let y0 = g.vec_normal(n);
        let mut ys = y0.clone();
        let mut yl = y0;
        simd::axpy_scalar(alpha, &x, &mut ys);
        simd::axpy_lanes(alpha, &x, &mut yl);
        for i in 0..n {
            assert_eq!(ys[i].to_bits(), yl[i].to_bits(), "n={n} i={i}");
        }
    });
}

#[test]
fn xpby_lanes_bitwise() {
    quick("simd-xpby", 200, |g| {
        let n = sweep_len(g);
        let beta = g.f64(-2.0, 2.0);
        let x = g.vec_normal(n);
        let y0 = g.vec_normal(n);
        let mut ys = y0.clone();
        let mut yl = y0;
        simd::xpby_scalar(&x, beta, &mut ys);
        simd::xpby_lanes(&x, beta, &mut yl);
        for i in 0..n {
            assert_eq!(ys[i].to_bits(), yl[i].to_bits(), "n={n} i={i}");
        }
    });
}

#[test]
fn mul_and_sub_into_lanes_bitwise() {
    quick("simd-mul-sub", 200, |g| {
        let n = sweep_len(g);
        let a = g.vec_normal(n);
        let b = g.vec_normal(n);
        let (mut os, mut ol) = (vec![0.0; n], vec![0.0; n]);
        simd::mul_into_scalar(&a, &b, &mut os);
        simd::mul_into_lanes(&a, &b, &mut ol);
        for i in 0..n {
            assert_eq!(os[i].to_bits(), ol[i].to_bits(), "mul n={n} i={i}");
        }
        simd::sub_into_scalar(&a, &b, &mut os);
        simd::sub_into_lanes(&a, &b, &mut ol);
        for i in 0..n {
            assert_eq!(os[i].to_bits(), ol[i].to_bits(), "sub n={n} i={i}");
        }
    });
}

#[test]
fn nan_propagates_through_both_paths() {
    quick("simd-nan", 50, |g| {
        let n = g.usize(1, 23);
        let mut a = g.vec_normal(n);
        let b = g.vec_normal(n);
        let poison = g.usize(0, n - 1);
        a[poison] = f64::NAN;
        // Reductions: both orders must be poisoned (class compare; NaN
        // payloads are not contractual).
        assert!(simd::dot_scalar(&a, &b).is_nan());
        assert!(simd::dot_fast(&a, &b).is_nan());
        // Elementwise: NaN lands in exactly the poisoned slot on both
        // paths, other slots stay bitwise-equal.
        let y0 = g.vec_normal(n);
        let mut ys = y0.clone();
        let mut yl = y0;
        simd::axpy_scalar(2.0, &a, &mut ys);
        simd::axpy_lanes(2.0, &a, &mut yl);
        for i in 0..n {
            if i == poison {
                assert!(ys[i].is_nan() && yl[i].is_nan());
            } else {
                assert_eq!(ys[i].to_bits(), yl[i].to_bits());
            }
        }
    });
}

#[test]
fn infinities_agree_in_class() {
    quick("simd-inf", 50, |g| {
        let n = g.usize(1, 23);
        let mut a = g.vec_f64(n, 0.5, 1.5); // same-sign: no ∞−∞
        let b = g.vec_f64(n, 0.5, 1.5);
        a[g.usize(0, n - 1)] = f64::INFINITY;
        let s = simd::dot_scalar(&a, &b);
        let f = simd::dot_fast(&a, &b);
        assert_eq!(s, f64::INFINITY);
        assert_eq!(f, f64::INFINITY);
        // Opposing infinities must poison both paths identically (NaN
        // from ∞ + (−∞), whichever order it is met in).
        let mut c = a.clone();
        c[0] = f64::INFINITY;
        c[n - 1] = f64::NEG_INFINITY;
        if n > 1 {
            assert!(simd::dot_scalar(&c, &b).is_nan());
            assert!(simd::dot_fast(&c, &b).is_nan());
        }
    });
}

#[test]
fn dense_matvec_modes_agree() {
    let _l = mode_lock();
    quick("simd-matvec", 60, |g| {
        let (m, n) = (g.usize(1, 24), g.usize(1, 24));
        let a = Mat::from_vec(m, n, g.vec_normal(m * n));
        let x = g.vec_normal(n);
        let ys = {
            let _g = ModeGuard::set(SimdMode::Scalar);
            a.matvec(&x)
        };
        let yo = {
            let _g = ModeGuard::set(SimdMode::Ordered);
            a.matvec(&x)
        };
        let yf = {
            let _g = ModeGuard::set(SimdMode::Fast);
            a.matvec(&x)
        };
        for i in 0..m {
            // Ordered keeps reductions sequential: bitwise.
            assert_eq!(ys[i].to_bits(), yo[i].to_bits(), "ordered row {i}");
            let bound = dot_bound(a.row(i), &x);
            assert!((ys[i] - yf[i]).abs() <= bound, "fast row {i}");
        }
        // Aᵀx is elementwise per row: bitwise in every mode.
        let xt = g.vec_normal(m);
        let ts = {
            let _g = ModeGuard::set(SimdMode::Scalar);
            a.matvec_t(&xt)
        };
        let tf = {
            let _g = ModeGuard::set(SimdMode::Fast);
            a.matvec_t(&xt)
        };
        for j in 0..n {
            assert_eq!(ts[j].to_bits(), tf[j].to_bits(), "matvec_t col {j}");
        }
    });
}

#[test]
fn csr_matvec_modes_agree() {
    let _l = mode_lock();
    quick("simd-csr-matvec", 60, |g| {
        let n = g.usize(1, 30);
        let m = g.usize(1, 30);
        let mut t = Triplets::new(n, m);
        for _ in 0..g.usize(0, n * m) {
            t.push(g.usize(0, n - 1), g.usize(0, m - 1), g.f64(-2.0, 2.0));
        }
        let a = t.to_csr();
        let x = g.vec_normal(m);
        let ys = {
            let _g = ModeGuard::set(SimdMode::Scalar);
            a.matvec(&x)
        };
        let yo = {
            let _g = ModeGuard::set(SimdMode::Ordered);
            a.matvec(&x)
        };
        let yf = {
            let _g = ModeGuard::set(SimdMode::Fast);
            a.matvec(&x)
        };
        for i in 0..n {
            assert_eq!(ys[i].to_bits(), yo[i].to_bits(), "ordered row {i}");
            let lo = a.indptr[i];
            let hi = a.indptr[i + 1];
            let gathered: Vec<f64> = a.indices[lo..hi].iter().map(|&c| x[c as usize]).collect();
            let bound = dot_bound(&a.data[lo..hi], &gathered);
            assert!((ys[i] - yf[i]).abs() <= bound, "fast row {i}");
        }
    });
}

#[test]
fn cg_solves_agree_across_modes() {
    // The mode changes CG's rounding trajectory (different iterates,
    // possibly different iteration counts) but both runs converge to
    // the same tolerance — so the *solutions* agree to solver accuracy,
    // and Ordered is bitwise with Scalar end to end.
    let _l = mode_lock();
    quick("simd-cg-modes", 25, |g| {
        let n = g.usize(2, 18);
        let b_mat = Mat::from_vec(n, n, g.vec_normal(n * n));
        let a = b_mat.transpose().matmul(&b_mat).add(&Mat::identity(n).scale(n as f64));
        let mut t = Triplets::new(n, n);
        for i in 0..n {
            for j in 0..n {
                t.push(i, j, a[(i, j)]);
            }
        }
        let csr = t.to_csr();
        let rhs = g.vec_normal(n);
        let solve = |mode: SimdMode| {
            let _g = ModeGuard::set(mode);
            let c = diffsim::math::cg::cg_operator(
                |x, out| out.copy_from_slice(&a.matvec(x)),
                &rhs,
                1e-12,
                20 * n,
            );
            let p = diffsim::math::cg::pcg_csr(&csr, &rhs, 1e-12, 100 * n);
            assert!(c.converged && p.converged, "mode {mode:?} failed to converge");
            (c.x, p.x)
        };
        let (cs, ps) = solve(SimdMode::Scalar);
        let (co, po) = solve(SimdMode::Ordered);
        let (cf, pf) = solve(SimdMode::Fast);
        for i in 0..n {
            assert_eq!(cs[i].to_bits(), co[i].to_bits(), "cg ordered dof {i}");
            assert_eq!(ps[i].to_bits(), po[i].to_bits(), "pcg ordered dof {i}");
            let scale = 1.0 + cs[i].abs();
            assert!((cs[i] - cf[i]).abs() <= 1e-8 * scale, "cg fast dof {i}");
            assert!((ps[i] - pf[i]).abs() <= 1e-8 * scale, "pcg fast dof {i}");
        }
    });
}

#[test]
fn env_parse_and_defaults_are_consistent() {
    // Pure parsing — no global state. The env override itself is
    // exercised by the CI matrix (DIFFSIM_SIMD=scalar/fast lanes).
    assert_eq!(SimdMode::parse("scalar"), Some(SimdMode::Scalar));
    assert_eq!(SimdMode::parse("fast"), Some(SimdMode::Fast));
    assert_eq!(SimdMode::parse("ordered"), Some(SimdMode::Ordered));
    assert_eq!(SimdMode::parse("auto"), Some(simd::default_mode()));
    assert_eq!(SimdMode::parse("bogus"), None);
    if simd::LANE_TARGET {
        assert_eq!(simd::default_mode(), SimdMode::Fast);
    } else {
        assert_eq!(simd::default_mode(), SimdMode::Scalar);
    }
}
