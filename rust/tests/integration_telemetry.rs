//! Telemetry integration: the JSONL trace emitted by an instrumented
//! rollout must agree *exactly* with the solver-side counters — span
//! counts per stage, GN/CG iteration totals — and the registry must
//! accumulate while enabled and stay silent while disabled.

use diffsim::bodies::{RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::obs;
use diffsim::util::json::Json;
use std::sync::Mutex;

/// Serialize the tests that toggle the process-wide enable flag.
static ENABLE_LOCK: Mutex<()> = Mutex::new(());

fn enable_lock() -> std::sync::MutexGuard<'static, ()> {
    ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Frozen ground slab + one cube dropping into resting contact: a
/// hand-steppable scene whose every step is either free flight (no
/// passes) or contact resolution (≥ 1 pass with GN iterations).
fn two_body_scene() -> Simulation {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.55, 0.0)));
    Simulation::new(sys, SimConfig { dt: 1.0 / 100.0, workers: 1, ..Default::default() })
}

#[test]
fn trace_span_counts_match_solver_counters_exactly() {
    let path = std::env::temp_dir().join("diffsim_itest_trace_exact.jsonl");
    let path_s = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);

    let steps = 40usize;
    let mut sim = two_body_scene();
    sim.set_trace(Some(obs::Trace::to_file(&path_s).unwrap()));
    let (mut passes_total, mut cg_total, mut gn_total) = (0usize, 0usize, 0usize);
    for _ in 0..steps {
        sim.step();
        passes_total += sim.last_stats.resolve_passes;
        cg_total += sim.last_stats.cg_iters;
        gn_total += sim.last_stats.gn_iters;
    }
    sim.set_trace(None); // flush

    let events: Vec<Json> = std::fs::read_to_string(&path)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    let count = |stage: &str| events.iter().filter(|e| e.str_or("span", "") == stage).count();
    let sum = |stage: &str, field: &str| -> usize {
        events
            .iter()
            .filter(|e| e.str_or("span", "") == stage)
            .map(|e| e.usize_or(field, 0))
            .sum()
    };

    // Once-per-step stages: exactly one event each per step.
    assert_eq!(count("integrate"), steps);
    assert_eq!(count("candidates"), steps);
    assert_eq!(count("commit"), steps);
    // Once-per-resolution-pass stages: exactly one event per counted
    // fail-safe pass.
    assert_eq!(count("solve_zones"), passes_total);
    assert_eq!(count("scatter"), passes_total);
    // Detection runs once per pass, plus the empty pass that terminates
    // a step's loop (absent when the loop exits on max_disp instead) —
    // and at least once every step.
    let detect = count("detect_and_zone");
    assert!(detect >= passes_total, "detect {detect} < passes {passes_total}");
    assert!(detect >= steps, "detect {detect} < steps {steps}");
    assert!(detect <= passes_total + steps, "detect {detect} > passes+steps");
    // Iteration totals in the trace equal the solver-reported ones.
    assert_eq!(sum("integrate", "cg_iters"), cg_total);
    assert_eq!(sum("scatter", "gn_iters"), gn_total);
    assert_eq!(sum("commit", "gn_iters"), gn_total);
    assert_eq!(sum("commit", "cg_iters"), cg_total);
    assert_eq!(sum("commit", "passes"), passes_total);
    // The scene did make contact: some GN work happened.
    assert!(passes_total > 0, "cube never made contact");
    assert!(gn_total > 0, "contact steps must report GN iterations");
    // Every event is schema-versioned and tagged with this sim's scene.
    for e in &events {
        assert_eq!(e.usize_or("v", 0), 1);
        assert_eq!(e.usize_or("scene", 99), 0);
        assert!(e.f64_or("dur_s", -1.0) >= 0.0);
    }
    // And the file passes the bench harness's schema checker.
    assert_eq!(diffsim::util::bench::check_trace_jsonl(&path_s).unwrap(), events.len());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn registry_counters_accumulate_when_enabled() {
    let _l = enable_lock();
    let steps = 30usize;
    let c_steps = obs::counter("engine.steps");
    let c_gn = obs::counter("solver.gn_iters");
    let h_int = obs::hist("step.integrate");
    let (s0, g0, h0) = (c_steps.get(), c_gn.get(), h_int.count());
    obs::enable();
    let mut sim = two_body_scene();
    sim.run(steps);
    let gn_reported: usize = sim.last_stats.gn_iters; // last step only
    obs::disable();
    // ≥, not ==: the registry is process-global and other tests in this
    // binary may be stepping concurrently.
    assert!(c_steps.get() - s0 >= steps as u64);
    assert!(h_int.count() - h0 >= steps as u64);
    assert!(c_gn.get() - g0 >= gn_reported as u64);
    // Disabled again: stepping no longer moves the counters beyond
    // other threads' activity — our own sim adds nothing.
    let mut quiet = two_body_scene();
    let before = c_steps.get();
    quiet.run(5);
    // Can't assert == because of concurrency, but our sim's own commit
    // path checked enabled() per step; sanity-check the flag is off.
    assert!(!obs::enabled());
    let _ = before;
}

#[test]
fn summary_has_sections_and_roundtrips() {
    let j = obs::summary();
    for k in
        ["schema_version", "counters", "gauges", "spans", "scratch", "pool", "arena", "memory",
         "coordinator"]
    {
        assert!(j.get(k).is_some(), "summary missing {k}");
    }
    let back = Json::parse(&j.to_string()).expect("summary serializes to valid json");
    assert_eq!(back.usize_or("schema_version", 0), 1);
}
