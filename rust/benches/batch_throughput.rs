//! Batch-throughput bench: aggregate steps/sec of `SceneBatch` vs
//! stepping the same scenes sequentially, across batch sizes, plus the
//! persistent-pool vs spawn-per-call comparison that gates the
//! worker-pool runtime and the pipelined-vs-blocking comparison that
//! gates `batch::pipeline`, and the incremental-collision refit vs
//! rebuild-every-step headline (results merged into `BENCH_pool.json`
//! — sections `batch_throughput`, `pipeline`, and `refit` — for perf
//! trajectory tracking; run with `--test` for the CI smoke config).
use diffsim::batch::pipeline::BatchPipeline;
use diffsim::batch::SceneBatch;
use diffsim::bodies::{RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::util::bench::{check_trace_jsonl, merge_section, time, Bench};
use diffsim::util::json::Json;
use diffsim::util::pool::{thread_spawns, Pool};

/// Contact-rich scene: ground + a leaning 4-cube stack.
fn pile_system() -> System {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for k in 0..4 {
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
            0.05 * k as f64,
            0.6 + 1.05 * k as f64,
            0.02 * k as f64,
        )));
    }
    sys
}

/// Small scene — ground + one settling cube. Physics work per step is
/// tiny, so per-call thread spawn/join dominates the spawn-per-call
/// baseline: the workload shape the persistent runtime targets.
fn small_system() -> System {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.8, 0.0)));
    sys
}

/// Time lockstep stepping of `scenes` copies of `base` on `pool`,
/// rebuilding the batch each iteration so every arm walks the same
/// trajectory. Returns (mean seconds, pool-layer thread spawns per
/// step, both measured after one warmup iteration).
fn time_lockstep(
    base: &System,
    cfg: &SimConfig,
    scenes: usize,
    steps: usize,
    iters: usize,
    pool: &Pool,
) -> (f64, f64) {
    let run = || {
        let mut sb = SceneBatch::from_scene(base, cfg, scenes, |i, sys| {
            let body = sys.rigids[1].clone();
            sys.rigids[1] = body.with_velocity(Vec3::new(0.1 * i as f64, 0.0, 0.0));
        });
        sb.set_pool(pool.clone());
        sb.run_lockstep(steps);
    };
    run(); // warmup: persistent workers exist after this
    let s0 = thread_spawns();
    let stats = time(0, iters, run);
    let spawns = (thread_spawns() - s0) as f64 / (iters * steps) as f64;
    (stats.mean(), spawns)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let mut b = Bench::new("batch_throughput");
    let steps = if smoke { 5 } else { 25 };
    let iters = if smoke { 1 } else { 3 };
    let sizes: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8, 16] };
    let workers = Pool::machine_workers();
    b.metric("workers", workers as f64, "threads");
    for &n in sizes {
        let base = pile_system();
        let solo_cfg = SimConfig { workers: 1, ..Default::default() };
        let mut solos: Vec<Simulation> =
            (0..n).map(|_| Simulation::new(base.clone(), solo_cfg.clone())).collect();
        let s_seq = time(1, iters, || {
            for sim in &mut solos {
                sim.run(steps);
            }
        });
        let batch_cfg = SimConfig { workers, ..Default::default() };
        let mut batch = SceneBatch::from_scene(&base, &batch_cfg, n, |_, _| {});
        let s_par = time(1, iters, || batch.run(steps));
        // Lockstep forward: per-step barrier, zone solves pooled across
        // scenes (the PJRT-batching layout; native solver here).
        let mut lock = SceneBatch::from_scene(&base, &batch_cfg, n, |_, _| {});
        let s_lock = time(1, iters, || lock.run_lockstep(steps));
        let sps_seq = (n * steps) as f64 / s_seq.mean().max(1e-12);
        let sps_par = (n * steps) as f64 / s_par.mean().max(1e-12);
        let sps_lock = (n * steps) as f64 / s_lock.mean().max(1e-12);
        b.metric(&format!("batch{n}/steps_per_s_sequential"), sps_seq, "steps/s");
        b.metric(&format!("batch{n}/steps_per_s_batched"), sps_par, "steps/s");
        b.metric(&format!("batch{n}/steps_per_s_lockstep"), sps_lock, "steps/s");
        b.metric(&format!("batch{n}/speedup"), sps_par / sps_seq, "x");
        b.metric(&format!("batch{n}/lockstep_speedup"), sps_lock / sps_seq, "x");
    }

    // ---- persistent pool vs spawn-per-call (→ BENCH_pool.json) ----
    // The lockstep forward issues several pool calls per simulated step
    // (stage barriers + one per fail-safe pass); with small scenes the
    // spawn-per-call baseline pays OS thread creation on every one.
    let mut pj = Json::obj();
    pj.set("workers", workers);
    let pool_iters = if smoke { 1 } else { 5 };
    let configs: &[(&str, System, usize, usize)] = &[
        // Acceptance config: 4 scenes × 64 steps, small scenes.
        ("small_scene", small_system(), 4, if smoke { 8 } else { 64 }),
        ("large_batch", pile_system(), if smoke { 4 } else { 16 }, if smoke { 4 } else { 25 }),
    ];
    for (label, base, scenes, steps) in configs {
        let cfg = SimConfig { workers, dt: 1.0 / 100.0, ..Default::default() };
        let (t_scoped, spawns_scoped) =
            time_lockstep(base, &cfg, *scenes, *steps, pool_iters, &Pool::scoped(workers));
        let (t_pers, spawns_pers) =
            time_lockstep(base, &cfg, *scenes, *steps, pool_iters, &Pool::shared(workers));
        let speedup = t_scoped / t_pers.max(1e-12);
        b.metric(&format!("{label}/spawn_per_call_s"), t_scoped, "s");
        b.metric(&format!("{label}/persistent_s"), t_pers, "s");
        b.metric(&format!("{label}/persistent_speedup"), speedup, "x");
        b.metric(&format!("{label}/spawn_per_call_spawns_per_step"), spawns_scoped, "threads");
        b.metric(&format!("{label}/persistent_spawns_per_step"), spawns_pers, "threads");
        let mut row = Json::obj();
        row.set("scenes", *scenes)
            .set("steps", *steps)
            .set("spawn_per_call_s", t_scoped)
            .set("persistent_s", t_pers)
            .set("persistent_speedup", speedup)
            .set("spawn_per_call_spawns_per_step", spawns_scoped)
            .set("persistent_spawns_per_step", spawns_pers);
        pj.set(label, row);
    }
    merge_section("BENCH_pool.json", "batch_throughput", pj);

    // ---- pipelined vs blocking (→ BENCH_pool.json#pipeline) ----
    // Blocking arm: the synchronous lockstep path on the shared
    // persistent pool (the fallback the fig7/fig8 drivers keep).
    // Pipelined arm: per-scene rollouts streamed through a bounded
    // in-flight window (batch::pipeline), per-scene "loss" read on the
    // submitter while slower scenes still step — the layout the
    // pipelined fig7/fig8 drivers run.
    let mut pp = Json::obj();
    pp.set("workers", workers).set("window", workers);
    for (label, base, scenes, steps) in configs {
        let cfg = SimConfig { workers, dt: 1.0 / 100.0, ..Default::default() };
        let (t_block, _) =
            time_lockstep(base, &cfg, *scenes, *steps, pool_iters, &Pool::shared(workers));
        let pipe = BatchPipeline::new(workers);
        let run_pipe = || {
            // Same per-scene customization as `time_lockstep`, so both
            // arms simulate identical trajectories.
            let losses = pipe.map_windowed(
                *scenes,
                |i| {
                    let mut sys = base.clone();
                    let body = sys.rigids[1].clone();
                    sys.rigids[1] = body.with_velocity(Vec3::new(0.1 * i as f64, 0.0, 0.0));
                    let mut sim =
                        Simulation::new(sys, SimConfig { workers: 1, ..cfg.clone() });
                    sim.run(*steps);
                    sim
                },
                |_i, sim| sim.sys.rigids[1].translation().y,
            );
            std::hint::black_box(losses);
        };
        run_pipe(); // warmup
        let t_pipe = time(0, pool_iters, run_pipe).mean();
        let speedup = t_block / t_pipe.max(1e-12);
        b.metric(&format!("{label}/pipeline_blocking_s"), t_block, "s");
        b.metric(&format!("{label}/pipeline_pipelined_s"), t_pipe, "s");
        b.metric(&format!("{label}/pipeline_speedup"), speedup, "x");
        let mut row = Json::obj();
        row.set("scenes", *scenes)
            .set("steps", *steps)
            .set("blocking_s", t_block)
            .set("pipelined_s", t_pipe)
            .set("pipelined_speedup", speedup);
        pp.set(label, row);
    }
    merge_section("BENCH_pool.json", "pipeline", pp);

    // ---- incremental refit vs rebuild-every-step (→ BENCH_pool.json#refit) ----
    // Headline for the incremental collision pipeline: forward-only
    // lockstep steps/sec with the cross-step cache (BVH refits + cull
    // cache) versus forcing a full surface rebuild every step, on the
    // acceptance configs (4 scenes × 64 steps small, 16 × 25
    // contact-rich). Both arms walk bitwise-identical trajectories, so
    // the ratio is pure pipeline overhead.
    let mut rj = Json::obj();
    rj.set("workers", workers);
    for (label, base, scenes, steps) in configs {
        let pool = Pool::shared(workers);
        let refit_cfg = SimConfig { workers, dt: 1.0 / 100.0, ..Default::default() };
        let rebuild_cfg = SimConfig { incremental_collision: false, ..refit_cfg.clone() };
        let (t_refit, _) = time_lockstep(base, &refit_cfg, *scenes, *steps, pool_iters, &pool);
        let (t_rebuild, _) =
            time_lockstep(base, &rebuild_cfg, *scenes, *steps, pool_iters, &pool);
        let sps_refit = (*scenes * *steps) as f64 / t_refit.max(1e-12);
        let sps_rebuild = (*scenes * *steps) as f64 / t_rebuild.max(1e-12);
        let speedup = t_rebuild / t_refit.max(1e-12);
        b.metric(&format!("{label}/refit_steps_per_s"), sps_refit, "steps/s");
        b.metric(&format!("{label}/rebuild_steps_per_s"), sps_rebuild, "steps/s");
        b.metric(&format!("{label}/refit_speedup"), speedup, "x");
        let mut row = Json::obj();
        row.set("scenes", *scenes)
            .set("steps", *steps)
            .set("refit_steps_per_s", sps_refit)
            .set("rebuild_steps_per_s", sps_rebuild)
            .set("refit_speedup", speedup);
        rj.set(label, row);
    }
    merge_section("BENCH_pool.json", "refit", rj);

    // ---- trace smoke (→ BENCH_trace.json) ----
    // Lockstep a 2-scene batch with the registry enabled and a JSONL
    // trace installed, validate the emitted file against the schema
    // checker, and merge the registry snapshot. This is the CI gate
    // against the trace path silently emitting nothing (or garbage).
    let trace_path = "bench_output/batch_throughput_trace.jsonl";
    let _ = std::fs::create_dir_all("bench_output");
    let trace_steps = if smoke { 8 } else { 32 };
    diffsim::obs::enable();
    match diffsim::obs::Trace::to_file(trace_path) {
        Ok(tr) => {
            let cfg = SimConfig { workers, dt: 1.0 / 100.0, ..Default::default() };
            let mut tb = SceneBatch::from_scene(&small_system(), &cfg, 2, |i, sys| {
                let body = sys.rigids[1].clone();
                sys.rigids[1] = body.with_velocity(Vec3::new(0.1 * i as f64, 0.0, 0.0));
            });
            tb.set_trace(Some(tr));
            tb.run_lockstep(trace_steps);
            tb.set_trace(None); // drops the last handle → flush
            let mut tj = Json::obj();
            tj.set("scenes", 2usize).set("steps", trace_steps);
            let check = check_trace_jsonl(trace_path);
            match &check {
                Ok(n) => {
                    b.metric("trace/events", *n as f64, "events");
                    tj.set("trace_events", *n).set("trace_schema_ok", true);
                }
                Err(e) => {
                    eprintln!("trace schema check FAILED: {e}");
                    tj.set("trace_schema_ok", false).set("trace_error", e.as_str());
                }
            }
            tj.set("summary", diffsim::obs::summary());
            merge_section("BENCH_trace.json", "batch_throughput", tj);
            diffsim::obs::disable();
            b.finish();
            if check.is_err() {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("trace smoke skipped: cannot create {trace_path}: {e}");
            diffsim::obs::disable();
            b.finish();
        }
    }
}
