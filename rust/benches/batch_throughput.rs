//! Batch-throughput bench: aggregate steps/sec of `SceneBatch` vs
//! stepping the same scenes sequentially, across batch sizes. The
//! acceptance target is >2x aggregate steps/sec at batch size 8 on a
//! multi-core host (scenes are embarrassingly parallel).
use diffsim::batch::SceneBatch;
use diffsim::bodies::{RigidBody, System};
use diffsim::engine::{SimConfig, Simulation};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::util::bench::{time, Bench};
use diffsim::util::pool::Pool;

/// Contact-rich scene: ground + a leaning 4-cube stack.
fn pile_system() -> System {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for k in 0..4 {
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
            0.05 * k as f64,
            0.6 + 1.05 * k as f64,
            0.02 * k as f64,
        )));
    }
    sys
}

fn main() {
    let mut b = Bench::new("batch_throughput");
    let steps = 25;
    let workers = Pool::default_for_machine().workers();
    b.metric("workers", workers as f64, "threads");
    for &n in &[1usize, 2, 4, 8, 16] {
        let base = pile_system();
        let solo_cfg = SimConfig { workers: 1, ..Default::default() };
        let mut solos: Vec<Simulation> =
            (0..n).map(|_| Simulation::new(base.clone(), solo_cfg.clone())).collect();
        let s_seq = time(1, 3, || {
            for sim in &mut solos {
                sim.run(steps);
            }
        });
        let batch_cfg = SimConfig { workers, ..Default::default() };
        let mut batch = SceneBatch::from_scene(&base, &batch_cfg, n, |_, _| {});
        let s_par = time(1, 3, || batch.run(steps));
        // Lockstep forward: per-step barrier, zone solves pooled across
        // scenes (the PJRT-batching layout; native solver here).
        let mut lock = SceneBatch::from_scene(&base, &batch_cfg, n, |_, _| {});
        let s_lock = time(1, 3, || lock.run_lockstep(steps));
        let sps_seq = (n * steps) as f64 / s_seq.mean().max(1e-12);
        let sps_par = (n * steps) as f64 / s_par.mean().max(1e-12);
        let sps_lock = (n * steps) as f64 / s_lock.mean().max(1e-12);
        b.metric(&format!("batch{n}/steps_per_s_sequential"), sps_seq, "steps/s");
        b.metric(&format!("batch{n}/steps_per_s_batched"), sps_par, "steps/s");
        b.metric(&format!("batch{n}/steps_per_s_lockstep"), sps_lock, "steps/s");
        b.metric(&format!("batch{n}/speedup"), sps_par / sps_seq, "x");
        b.metric(&format!("batch{n}/lockstep_speedup"), sps_lock / sps_seq, "x");
    }
    b.finish();
}
