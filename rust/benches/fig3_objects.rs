//! Fig. 3 (top): runtime + memory vs number of objects, ours vs MPM.
//! Regenerates the paper's series shape: ours linear, MPM cubic → OOM.
use diffsim::experiments::scalability::{mpm_objects, ours_objects};
use diffsim::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig3_objects");
    let steps = 20;
    for n in [20usize, 50, 100, 200] {
        let (t, mem) = ours_objects(n, steps);
        b.metric(&format!("ours/n{n}/time"), t, "s");
        b.metric(&format!("ours/n{n}/mem"), mem as f64 / 1e6, "MB");
        let (mt, mm, note) = mpm_objects(n, steps, 128);
        b.metric(
            &format!("mpm/n{n}/time ({note})"),
            mt.unwrap_or(f64::NAN),
            "s",
        );
        b.metric(&format!("mpm/n{n}/mem"), mm as f64 / 1e6, "MB");
    }
    b.finish();
}
