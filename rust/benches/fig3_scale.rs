//! Fig. 3 (bottom): runtime + memory vs cloth:bunny scale ratio.
//! Ours stays ~constant; the grid-based baseline grows cubically.
use diffsim::experiments::scalability::{mpm_scale, ours_scale};
use diffsim::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig3_scale");
    let steps = 20;
    for r in [1usize, 2, 4, 6, 8, 10] {
        let (t, mem) = ours_scale(r as f64, steps);
        b.metric(&format!("ours/ratio{r}/time"), t, "s");
        b.metric(&format!("ours/ratio{r}/mem"), mem as f64 / 1e6, "MB");
        let (mt, mm, note) = mpm_scale(r as f64, steps, 160);
        b.metric(&format!("mpm/ratio{r}/time ({note})"), mt.unwrap_or(f64::NAN), "s");
        b.metric(&format!("mpm/ratio{r}/mem"), mm as f64 / 1e6, "MB");
    }
    b.finish();
}
