//! Table 1: backprop seconds/step — global LCP-style vs local zones.
use diffsim::engine::CollisionMode;
use diffsim::experiments::ablation_lcp::backprop_time;
use diffsim::util::bench::Bench;

fn main() {
    let mut b = Bench::new("table1_lcp");
    // Paper sizes are 100/200/300; bench defaults stay CI-friendly.
    for n in [50usize, 100] {
        let global = backprop_time(n, CollisionMode::Global, 2);
        let local = backprop_time(n, CollisionMode::LocalZones, 2);
        b.report(&format!("lcp-global/n{n}"), &global);
        b.report(&format!("ours-local/n{n}"), &local);
        b.metric(&format!("speedup/n{n}"), global.mean() / local.mean().max(1e-12), "x");
    }
    b.finish();
}
