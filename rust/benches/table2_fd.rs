//! Table 2: backprop seconds/step — dense KKT ("W/o FD") vs QR fast diff.
use diffsim::engine::DiffMode;
use diffsim::experiments::ablation_fd::backprop_time;
use diffsim::util::bench::Bench;

fn main() {
    let mut b = Bench::new("table2_fd");
    for n in [50usize, 100] {
        let dense = backprop_time(n, DiffMode::Dense, 2);
        let qr = backprop_time(n, DiffMode::Qr, 2);
        b.report(&format!("wofd-dense/n{n}"), &dense);
        b.report(&format!("ours-qr/n{n}"), &qr);
        b.metric(&format!("speedup/n{n}"), dense.mean() / qr.mean().max(1e-12), "x");
    }
    b.finish();
}
