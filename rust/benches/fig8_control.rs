//! Fig. 8: learning-control loss curves — ours (BPTT through the
//! simulator) vs DDPG on the same episode budget.
use diffsim::experiments::control::{train_ddpg_sticks, train_ours_sticks};
use diffsim::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig8_control");
    let episodes = 12;
    let ours = train_ours_sticks(episodes, 11);
    let ddpg = train_ddpg_sticks(episodes, 11);
    for (i, l) in ours.iter().enumerate() {
        b.metric(&format!("ours/episode{i}"), *l, "final dist^2");
    }
    for (i, l) in ddpg.iter().enumerate() {
        b.metric(&format!("ddpg/episode{i}"), *l, "final dist^2");
    }
    let tail = |v: &[f64]| v.iter().rev().take(5).sum::<f64>() / 5.0;
    b.metric("ours/tail5", tail(&ours), "final dist^2");
    b.metric("ddpg/tail5", tail(&ddpg), "final dist^2");
    b.finish();
}
