//! Micro-benchmarks of the per-step hot paths (the §Perf working set):
//! BVH build/refit, CCD narrowphase, zone solve, zone backward (QR vs
//! dense), cloth implicit solve, and the PJRT call overhead.
use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::collision::zones::build_zones;
use diffsim::collision::{detect, surfaces_from_system};
use diffsim::diff::implicit::{backward_dense, backward_qr};
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, icosphere, unit_box};
use diffsim::solver::implicit_euler::cloth_implicit_step;
use diffsim::solver::zone_solver::ZoneProblem;
use diffsim::util::bench::{time, Bench};

fn main() {
    let mut b = Bench::new("micro_hotpaths");

    // BVH over a 1280-face mesh.
    let mesh = icosphere(1.0, 3);
    let aabbs: Vec<_> = (0..mesh.n_faces())
        .map(|f| {
            let [i, j, k] = mesh.faces[f];
            diffsim::collision::aabb::Aabb::from_points(&[
                mesh.verts[i as usize],
                mesh.verts[j as usize],
                mesh.verts[k as usize],
            ])
        })
        .collect();
    b.report("bvh/build 1280 faces", &time(3, 30, || {
        std::hint::black_box(diffsim::collision::bvh::Bvh::build(&aabbs));
    }));
    let mut bvh = diffsim::collision::bvh::Bvh::build(&aabbs);
    b.report("bvh/refit 1280 faces", &time(3, 100, || {
        bvh.refit(&aabbs);
    }));

    // Full detect() on a 27-cube pile.
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for k in 0..27 {
        let (i, j, l) = (k % 3, (k / 3) % 3, k / 9);
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
            1.05 * i as f64,
            0.505 + 1.02 * l as f64,
            1.05 * j as f64,
        )));
    }
    let x1: Vec<Vec<Vec3>> = sys.rigids.iter().map(|r| r.world_verts()).collect();
    b.report("detect/27-cube pile", &time(2, 20, || {
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        std::hint::black_box(detect(&surfs, 1e-3));
    }));

    // Zone solve + backwards on a realistic zone.
    let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
    let (impacts, _) = detect(&surfs, 1e-3);
    let zones = build_zones(&sys, &impacts);
    let rigid_q: Vec<[f64; 6]> = sys.rigids.iter().map(|r| r.q).collect();
    if let Some(z) = zones.iter().max_by_key(|z| z.n_dofs()) {
        let zp = ZoneProblem::build(&sys, z, &rigid_q, &[], 1e-3);
        b.metric("zone/dofs", zp.n as f64, "n");
        b.metric("zone/constraints", zp.constraints.len() as f64, "m");
        b.report("zone/solve", &time(2, 10, || {
            std::hint::black_box(zp.solve());
        }));
        let sol = zp.solve();
        let g: Vec<f64> = (0..zp.n).map(|i| (i as f64 * 0.37).sin()).collect();
        b.report("zone/backward-qr", &time(3, 50, || {
            std::hint::black_box(backward_qr(&zp, &sol, &g));
        }));
        b.report("zone/backward-dense", &time(3, 50, || {
            std::hint::black_box(backward_dense(&zp, &sol, &g));
        }));
    }

    // Cloth implicit step, 33×33 grid.
    let cloth = Cloth::from_grid(cloth_grid(32, 32, 2.0, 2.0), 0.3, 3000.0, 2.0, 1.0);
    b.report("cloth/implicit step 33x33", &time(2, 10, || {
        std::hint::black_box(cloth_implicit_step(&cloth, 0.005, Vec3::new(0.0, -9.8, 0.0)));
    }));

    // PJRT call overhead (if artifacts exist).
    if let Ok(rt) = diffsim::runtime::Runtime::load_default() {
        let q = vec![0f32; 128 * 6];
        let p = vec![0f32; 128 * 3];
        rt.warmup("rigid_transform_b128").ok();
        b.report("pjrt/rigid_transform_b128 call", &time(3, 30, || {
            std::hint::black_box(rt.call_f32("rigid_transform_b128", &[&q, &p]).unwrap());
        }));
    }
    b.finish();
}
