//! Micro-benchmarks of the per-step hot paths (the §Perf working set):
//! BVH build/refit, CCD narrowphase, zone solve, zone backward (QR vs
//! dense), cloth implicit solve, pool dispatch (persistent vs
//! spawn-per-call, → `BENCH_pool.json`), and the PJRT call overhead.
//! Run with `--test` for the CI smoke config.
use diffsim::batch::{FaultPolicy, SceneBatch};
use diffsim::bodies::{Cloth, RigidBody, System};
use diffsim::collision::zones::build_zones;
use diffsim::collision::{detect, surfaces_from_system};
use diffsim::diff::implicit::{backward_dense, backward_qr};
use diffsim::engine::SimConfig;
use diffsim::math::cg::pcg_csr;
use diffsim::math::dense::Mat;
use diffsim::math::simd::{self, SimdMode};
use diffsim::math::sparse::Triplets;
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, cloth_grid, icosphere, unit_box};
use diffsim::solver::implicit_euler::cloth_implicit_step;
use diffsim::solver::zone_solver::ZoneProblem;
use diffsim::util::bench::{merge_section, time, Bench};
use diffsim::util::json::Json;
use diffsim::util::pool::{thread_spawns, Pool};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale = |iters: usize| if smoke { 1 } else { iters };
    let mut b = Bench::new("micro_hotpaths");

    // Pool dispatch overhead: one `map` over N small tasks — the shape
    // of a per-pass zone-solve barrier. The persistent runtime hands
    // indices to parked workers; the scoped baseline spawns and joins
    // OS threads every call.
    let w = Pool::machine_workers();
    let busy = |i: usize| {
        let mut acc = 0u64;
        for k in 0..2_000u64 {
            acc = acc.wrapping_mul(31).wrapping_add(k ^ i as u64);
        }
        acc
    };
    let persistent = Pool::shared(w);
    persistent.map(8, busy); // warmup: global workers exist after this
    let iters = scale(200);
    let s_pers = time(5, iters, || {
        std::hint::black_box(persistent.map(8, busy));
    });
    let spawns0 = thread_spawns();
    persistent.map(8, busy);
    let pers_spawns_per_call = (thread_spawns() - spawns0) as f64;
    let scoped = Pool::scoped(w);
    let s_scoped = time(5, iters, || {
        std::hint::black_box(scoped.map(8, busy));
    });
    let spawns1 = thread_spawns();
    scoped.map(8, busy);
    let scoped_spawns_per_call = (thread_spawns() - spawns1) as f64;
    b.report("pool/map8 persistent", &s_pers);
    b.report("pool/map8 spawn-per-call", &s_scoped);
    b.metric("pool/map8 persistent speedup", s_scoped.mean() / s_pers.mean().max(1e-12), "x");
    b.metric("pool/map8 persistent spawns/call", pers_spawns_per_call, "threads");
    b.metric("pool/map8 scoped spawns/call", scoped_spawns_per_call, "threads");
    let mut pj = Json::obj();
    pj.set("workers", w)
        .set("map8_persistent_s", s_pers.mean())
        .set("map8_spawn_per_call_s", s_scoped.mean())
        .set("map8_persistent_speedup", s_scoped.mean() / s_pers.mean().max(1e-12))
        .set("map8_persistent_spawns_per_call", pers_spawns_per_call)
        .set("map8_spawn_per_call_spawns_per_call", scoped_spawns_per_call);

    // Telemetry overhead: the acceptance lockstep config (4 scenes ×
    // 64 steps, small scene) with the registry disabled vs enabled.
    // Disabled must be within noise of the pre-telemetry baseline —
    // every instrumentation point is one relaxed atomic load.
    let tele_steps = if smoke { 8 } else { 64 };
    let tele_iters = if smoke { 1 } else { 5 };
    let mut tsys = System::new();
    tsys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    tsys.add_rigid(
        RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(0.0, 0.8, 0.0)),
    );
    let tele_cfg = SimConfig { workers: w, dt: 1.0 / 100.0, ..Default::default() };
    let run_lockstep = || {
        let mut sb = SceneBatch::from_scene(&tsys, &tele_cfg, 4, |i, sys| {
            let body = sys.rigids[1].clone();
            sys.rigids[1] = body.with_velocity(Vec3::new(0.1 * i as f64, 0.0, 0.0));
        });
        sb.run_lockstep(tele_steps);
    };
    diffsim::obs::disable();
    run_lockstep(); // warmup
    let s_dis = time(0, tele_iters, || run_lockstep());
    diffsim::obs::enable();
    run_lockstep(); // warmup under the enabled registry
    let s_en = time(0, tele_iters, || run_lockstep());
    diffsim::obs::disable();
    let overhead = s_en.mean() / s_dis.mean().max(1e-12);
    b.report("telemetry/lockstep4x64 disabled", &s_dis);
    b.report("telemetry/lockstep4x64 enabled", &s_en);
    b.metric("telemetry/enabled_overhead", overhead, "x");
    pj.set("telemetry_lockstep4_steps", tele_steps)
        .set("telemetry_disabled_s", s_dis.mean())
        .set("telemetry_enabled_s", s_en.mean())
        .set("telemetry_enabled_overhead", overhead)
        .set(
            "telemetry_disabled_steps_per_s",
            (4 * tele_steps) as f64 / s_dis.mean().max(1e-12),
        );
    // Fault-layer overhead: the same lockstep config under the default
    // FailFast policy (the original unguarded stage bodies — the
    // bitwise-parity path) vs Isolate (per-scene containment:
    // catch_unwind + finite gates around every stage). The injection
    // hooks themselves are `const false` without `--features
    // faultinject` and compile out, so FailFast must stay within noise
    // of a tree without the fault layer.
    let run_policy = |policy: FaultPolicy| {
        let mut sb = SceneBatch::from_scene(&tsys, &tele_cfg, 4, |i, sys| {
            let body = sys.rigids[1].clone();
            sys.rigids[1] = body.with_velocity(Vec3::new(0.1 * i as f64, 0.0, 0.0));
        });
        sb.set_fault_policy(policy);
        sb.run_lockstep(tele_steps);
    };
    run_policy(FaultPolicy::FailFast); // warmup
    let s_ff = time(0, tele_iters, || run_policy(FaultPolicy::FailFast));
    let s_iso = time(0, tele_iters, || run_policy(FaultPolicy::Isolate));
    let fault_overhead = s_iso.mean() / s_ff.mean().max(1e-12);
    b.report("fault/lockstep4 failfast", &s_ff);
    b.report("fault/lockstep4 isolate", &s_iso);
    b.metric("fault/isolate_overhead", fault_overhead, "x");
    pj.set("fault_failfast_s", s_ff.mean())
        .set("fault_isolate_s", s_iso.mean())
        .set("fault_isolate_overhead", fault_overhead);
    merge_section("BENCH_pool.json", "micro_hotpaths", pj);

    // BVH over a 1280-face mesh.
    let mesh = icosphere(1.0, 3);
    let aabbs: Vec<_> = (0..mesh.n_faces())
        .map(|f| {
            let [i, j, k] = mesh.faces[f];
            diffsim::collision::aabb::Aabb::from_points(&[
                mesh.verts[i as usize],
                mesh.verts[j as usize],
                mesh.verts[k as usize],
            ])
        })
        .collect();
    b.report("bvh/build 1280 faces", &time(3, scale(30), || {
        std::hint::black_box(diffsim::collision::bvh::Bvh::build(&aabbs));
    }));
    let mut bvh = diffsim::collision::bvh::Bvh::build(&aabbs);
    b.report("bvh/refit 1280 faces", &time(3, scale(100), || {
        bvh.refit(&aabbs);
    }));
    // The per-step incremental refresh: copy new positions into the
    // surface in place, recompute face AABBs, refit the tree — zero
    // allocation (the `&[Vec3]` signature is what keeps the cloth path
    // from cloning x1 every pass).
    let mut ssys = System::new();
    ssys.add_rigid(RigidBody::from_mesh(icosphere(1.0, 3), 1.0));
    let sx: Vec<Vec<Vec3>> = ssys.rigids.iter().map(|r| r.world_verts()).collect();
    let mut surf = surfaces_from_system(&ssys, &sx, &[], 1e-3)
        .into_iter()
        .next()
        .expect("one rigid => one surface");
    b.report("surface/update_candidates 1280 faces", &time(3, scale(100), || {
        surf.update_candidates(&sx[0], 1e-3);
    }));

    // Full detect() on a 27-cube pile.
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for k in 0..27 {
        let (i, j, l) = (k % 3, (k / 3) % 3, k / 9);
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
            1.05 * i as f64,
            0.505 + 1.02 * l as f64,
            1.05 * j as f64,
        )));
    }
    let x1: Vec<Vec<Vec3>> = sys.rigids.iter().map(|r| r.world_verts()).collect();
    b.report("detect/27-cube pile", &time(2, scale(20), || {
        let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
        std::hint::black_box(detect(&surfs, 1e-3));
    }));

    // Zone solve + backwards on a realistic zone.
    let surfs = surfaces_from_system(&sys, &x1, &[], 1e-3);
    let (impacts, _) = detect(&surfs, 1e-3);
    let zones = build_zones(&sys, &impacts);
    let rigid_q: Vec<[f64; 6]> = sys.rigids.iter().map(|r| r.q).collect();
    if let Some(z) = zones.iter().max_by_key(|z| z.n_dofs()) {
        let zp = ZoneProblem::build(&sys, z, &rigid_q, &[], 1e-3);
        b.metric("zone/dofs", zp.n as f64, "n");
        b.metric("zone/constraints", zp.constraints.len() as f64, "m");
        b.report("zone/solve", &time(2, scale(10), || {
            std::hint::black_box(zp.solve());
        }));
        let sol = zp.solve();
        let g: Vec<f64> = (0..zp.n).map(|i| (i as f64 * 0.37).sin()).collect();
        b.report("zone/backward-qr", &time(3, scale(50), || {
            std::hint::black_box(backward_qr(&zp, &sol, &g));
        }));
        b.report("zone/backward-dense", &time(3, scale(50), || {
            std::hint::black_box(backward_dense(&zp, &sol, &g));
        }));
    }

    // Cloth implicit step, 33×33 grid.
    let cloth = Cloth::from_grid(cloth_grid(32, 32, 2.0, 2.0), 0.3, 3000.0, 2.0, 1.0);
    b.report("cloth/implicit step 33x33", &time(2, scale(10), || {
        std::hint::black_box(cloth_implicit_step(&cloth, 0.005, Vec3::new(0.0, -9.8, 0.0)));
    }));

    // SIMD kernel modes: each vectorized hot kernel timed under the
    // Scalar oracle and the Fast lane path, plus the acceptance 4×64
    // lockstep config end to end (→ `BENCH_pool.json#simd`). The mode
    // is process-global; benches run sequentially, so set/restore
    // around the section is safe.
    let prev_mode = simd::mode();
    let mut sj = Json::obj();
    sj.set("lane_target", simd::LANE_TARGET)
        .set("lanes", simd::LANES as f64)
        .set("smoke", smoke);
    {
        let mut pair = |b: &mut Bench,
                        sj: &mut Json,
                        label: &str,
                        key: &str,
                        warm: usize,
                        iters: usize,
                        f: &mut dyn FnMut()| {
            simd::set_mode(SimdMode::Scalar);
            let s = time(warm, iters, || f());
            simd::set_mode(SimdMode::Fast);
            let l = time(warm, iters, || f());
            b.report(&format!("simd/{label} scalar"), &s);
            b.report(&format!("simd/{label} fast"), &l);
            let speedup = s.mean() / l.mean().max(1e-12);
            b.metric(&format!("simd/{label} speedup"), speedup, "x");
            sj.set(&format!("{key}_scalar_s"), s.mean())
                .set(&format!("{key}_fast_s"), l.mean())
                .set(&format!("{key}_speedup"), speedup);
            (s.mean(), l.mean())
        };

        // Dense matvec at the implicit-cloth system shape (96×96).
        let dn = 96;
        let dense = Mat::from_vec(dn, dn, (0..dn * dn).map(|i| (i as f64 * 0.37).sin()).collect());
        let dx: Vec<f64> = (0..dn).map(|i| (i as f64 * 0.61).cos()).collect();
        let mut dy = Vec::new();
        pair(&mut b, &mut sj, "matvec96", "matvec96", 3, scale(2000), &mut || {
            dense.matvec_into(&dx, &mut dy);
            std::hint::black_box(&dy);
        });

        // CSR matvec and the full PCG solve on an SPD 3-point
        // Laplacian (n = 3000) — the CG inner-loop row shapes.
        let cn = 3000;
        let mut t = Triplets::new(cn, cn);
        for i in 0..cn {
            t.push(i, i, 4.0);
            if i + 1 < cn {
                t.push(i, i + 1, -1.0);
                t.push(i + 1, i, -1.0);
            }
        }
        let a = t.to_csr();
        let cb: Vec<f64> = (0..cn).map(|i| (i as f64 * 0.17).sin()).collect();
        let mut cy = vec![0.0; cn];
        pair(&mut b, &mut sj, "csr_matvec3000", "csr_matvec3000", 3, scale(500), &mut || {
            a.matvec_into(&cb, &mut cy);
            std::hint::black_box(&cy);
        });
        pair(&mut b, &mut sj, "pcg3000", "pcg3000", 1, scale(20), &mut || {
            std::hint::black_box(pcg_csr(&a, &cb, 1e-10, 200));
        });

        // Zone eval/jacobian on the largest 27-cube-pile zone.
        if let Some(z) = zones.iter().max_by_key(|z| z.n_dofs()) {
            let zp = ZoneProblem::build(&sys, z, &rigid_q, &[], 1e-3);
            let zq: Vec<f64> = zp.q0.iter().enumerate().map(|(i, v)| v + 0.003 * i as f64).collect();
            let mut zout = Vec::new();
            let mut zjac = Mat::zeros(0, 0);
            pair(&mut b, &mut sj, "zone_eval", "zone_eval", 3, scale(2000), &mut || {
                zp.eval_into(&zq, &mut zout);
                std::hint::black_box(&zout);
            });
            pair(&mut b, &mut sj, "zone_jacobian", "zone_jacobian", 3, scale(500), &mut || {
                zp.jacobian_into(&zq, &mut zjac);
                std::hint::black_box(&zjac);
            });
        }

        // The acceptance headline: 4 scenes × 64 lockstep steps,
        // scalar oracle vs Fast lanes, in steps per second.
        let (ls_s, ls_f) =
            pair(&mut b, &mut sj, "lockstep4x64", "lockstep4x64", 0, tele_iters, &mut || {
                run_lockstep();
            });
        sj.set("lockstep4x64_steps", tele_steps as f64)
            .set("lockstep4x64_scalar_steps_per_s", (4 * tele_steps) as f64 / ls_s.max(1e-12))
            .set("lockstep4x64_fast_steps_per_s", (4 * tele_steps) as f64 / ls_f.max(1e-12));
    }
    simd::set_mode(prev_mode);
    merge_section("BENCH_pool.json", "simd", sj);

    // PJRT call overhead (if artifacts exist).
    if let Ok(rt) = diffsim::runtime::Runtime::load_default() {
        let q = vec![0f32; 128 * 6];
        let p = vec![0f32; 128 * 3];
        rt.warmup("rigid_transform_b128").ok();
        b.report("pjrt/rigid_transform_b128 call", &time(3, scale(30), || {
            std::hint::black_box(rt.call_f32("rigid_transform_b128", &[&q, &p]).unwrap());
        }));
    }
    b.finish();
}
