//! Fig. 7: inverse problem — gradient episodes vs CMA-ES episodes to a
//! given loss (the sample-efficiency series the paper plots).
use diffsim::experiments::inverse::{optimize_cmaes, optimize_gradient};
use diffsim::math::Vec3;
use diffsim::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig7_inverse");
    let target = Vec3::new(0.4, 0.0, 0.2);
    let g = optimize_gradient(target, 10);
    for (i, l) in g.iter().enumerate() {
        b.metric(&format!("gradient/episode{i}"), *l, "loss");
    }
    let c = optimize_cmaes(target, 60, 42);
    for i in [0usize, 9, 29, 59] {
        if i < c.len() {
            b.metric(&format!("cmaes/episode{i}"), c[i], "best loss");
        }
    }
    b.metric("gradient/final", *g.last().unwrap(), "loss");
    b.metric("cmaes/final", *c.last().unwrap(), "loss");
    b.finish();
}
