//! Batch-extended Fig-3 memory accounting: peak *logical* bytes of a
//! multi-scene batch under three buffer regimes —
//!
//! * `alloc`     — no pooling, instrumented plain allocation
//!                 (`BatchArena::tracked`): the transient live peak.
//! * `per_scene` — one private pooled arena per scene: the
//!                 `n_scenes × worst_case` retention the ROADMAP item
//!                 calls out (every scene keeps its own warm buffers).
//! * `shared`    — one cross-scene `BatchArena` (the `SceneBatch`
//!                 default): retention bounded by the worker budget,
//!                 not the population size.
//!
//! The headline acceptance row is `forward16/peak_ratio_shared_vs_per_scene`
//! (expected well below 0.5 for a 16-scene batch on a 4-worker budget).
//! A taped configuration additionally shows the tape bytes batched
//! fig7/fig8-style rollouts now register under `MemCategory::Tape`.
//! Results are merged into `BENCH_memory.json` (section `batch_memory`)
//! via `bench::merge_section`; run with `--test` for the CI smoke
//! config.
use diffsim::batch::SceneBatch;
use diffsim::bodies::{RigidBody, System};
use diffsim::engine::backward::LossGrad;
use diffsim::engine::SimConfig;
use diffsim::math::Vec3;
use diffsim::mesh::primitives::{box_mesh, unit_box};
use diffsim::util::arena::{ArenaStats, BatchArena, DEFAULT_RETAIN_CAP};
use diffsim::util::bench::{merge_section, Bench};
use diffsim::util::json::Json;
use diffsim::util::memory::{fmt_bytes, MemCategory, MemTracker};
use std::sync::Arc;

/// Contact-rich scene: ground + a leaning 4-cube stack (same shape as
/// the batch_throughput bench, so the two benches describe one workload).
fn pile_system() -> System {
    let mut sys = System::new();
    sys.add_rigid(
        RigidBody::frozen_from_mesh(box_mesh(Vec3::new(10.0, 0.5, 10.0)))
            .with_position(Vec3::new(0.0, -0.5, 0.0)),
    );
    for k in 0..4 {
        sys.add_rigid(RigidBody::from_mesh(unit_box(), 1.0).with_position(Vec3::new(
            0.05 * k as f64,
            0.6 + 1.05 * k as f64,
            0.02 * k as f64,
        )));
    }
    sys
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Alloc,
    PerScene,
    Shared,
}

impl Mode {
    fn label(self) -> &'static str {
        match self {
            Mode::Alloc => "alloc",
            Mode::PerScene => "per_scene",
            Mode::Shared => "shared",
        }
    }
}

struct Measured {
    peak: usize,
    cat_peak: [usize; 4],
    tape_current: usize,
    arena: ArenaStats,
}

/// Run `scenes` lockstep copies of the pile for `steps` steps on a
/// `workers`-budget pool under `mode`, against a fresh tracker; `taped`
/// runs a rollout_grad_lockstep (fig8-style) instead of a forward-only
/// run (fig7-style).
fn run_config(mode: Mode, scenes: usize, steps: usize, workers: usize, taped: bool) -> Measured {
    let tracker = Arc::new(MemTracker::new());
    let cfg = SimConfig { workers, dt: 1.0 / 100.0, ..Default::default() };
    let mut sb = SceneBatch::from_scene(&pile_system(), &cfg, scenes, |i, sys| {
        let body = sys.rigids[1].clone();
        sys.rigids[1] = body.with_velocity(Vec3::new(0.1 * i as f64, 0.0, 0.0));
    });
    // Keep handles to every arena so stats survive the run.
    let arenas: Vec<BatchArena> = match mode {
        Mode::Alloc => {
            let a = BatchArena::tracked_with(tracker.clone());
            sb.set_arena(a.clone());
            vec![a]
        }
        Mode::Shared => {
            let a = BatchArena::pooled_with(DEFAULT_RETAIN_CAP, tracker.clone());
            sb.set_arena(a.clone());
            vec![a]
        }
        Mode::PerScene => {
            let arenas: Vec<BatchArena> = (0..scenes)
                .map(|_| BatchArena::pooled_with(DEFAULT_RETAIN_CAP, tracker.clone()))
                .collect();
            for (sim, a) in sb.sims_mut().iter_mut().zip(&arenas) {
                sim.set_arena(a.clone());
            }
            arenas
        }
    };
    if taped {
        let _ = sb.rollout_grad_lockstep(
            steps,
            |_| (),
            |_, _i, _s, _sim| {},
            |_, sim, _| {
                let x = sim.sys.rigids[1].translation().x;
                let mut seed = LossGrad::zeros(sim);
                seed.rigid_q[1][3] = 2.0 * x;
                (x * x, seed)
            },
        );
    } else {
        sb.run_lockstep(steps);
    }
    let mut agg = ArenaStats::default();
    for a in &arenas {
        let s = a.stats();
        agg.takes += s.takes;
        agg.hits += s.hits;
        agg.misses += s.misses;
        agg.parks += s.parks;
        agg.evictions += s.evictions;
        agg.retained_bytes += s.retained_bytes;
        agg.retained_buffers += s.retained_buffers;
    }
    Measured {
        peak: tracker.peak(),
        cat_peak: [
            tracker.peak_cat(MemCategory::Tape),
            tracker.peak_cat(MemCategory::Contacts),
            tracker.peak_cat(MemCategory::Solver),
            tracker.peak_cat(MemCategory::ArenaRetained),
        ],
        tape_current: tracker.current_cat(MemCategory::Tape),
        arena: agg,
    }
}

fn row_for(m: &Measured) -> Json {
    let mut j = Json::obj();
    j.set("peak_bytes", m.peak)
        .set("tape_peak_bytes", m.cat_peak[0])
        .set("contacts_peak_bytes", m.cat_peak[1])
        .set("solver_peak_bytes", m.cat_peak[2])
        .set("arena_retained_peak_bytes", m.cat_peak[3])
        .set("tape_final_bytes", m.tape_current)
        .set("arena_takes", m.arena.takes)
        .set("arena_hits", m.arena.hits)
        .set("arena_misses", m.arena.misses)
        .set("arena_evictions", m.arena.evictions)
        .set("arena_hit_rate", m.arena.hit_rate())
        .set("arena_retained_bytes", m.arena.retained_bytes)
        .set("arena_retained_buffers", m.arena.retained_buffers);
    j
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let mut b = Bench::new("batch_memory");
    // Memory scales with the worker budget under the shared arena, so
    // pin it: 16 scenes stepped 4-wide is the acceptance geometry.
    let workers = 4;
    let configs: &[(&str, usize, usize, bool)] = if smoke {
        &[("forward16", 8, 10, false), ("taped4", 2, 6, true)]
    } else {
        &[("forward16", 16, 50, false), ("taped4", 4, 25, true)]
    };
    let mut section = Json::obj();
    section.set("workers", workers).set("smoke", smoke);
    for &(name, scenes, steps, taped) in configs {
        let mut cj = Json::obj();
        cj.set("scenes", scenes).set("steps", steps).set("taped", taped);
        let mut peaks = [0usize; 3];
        for (k, mode) in [Mode::Alloc, Mode::PerScene, Mode::Shared].into_iter().enumerate() {
            let m = run_config(mode, scenes, steps, workers, taped);
            peaks[k] = m.peak;
            b.metric(
                &format!("{name}/{}/peak_logical", mode.label()),
                m.peak as f64,
                "bytes",
            );
            if mode != Mode::Alloc {
                b.metric(
                    &format!("{name}/{}/arena_hit_rate", mode.label()),
                    m.arena.hit_rate(),
                    "frac",
                );
                b.metric(
                    &format!("{name}/{}/arena_retained", mode.label()),
                    m.arena.retained_bytes as f64,
                    "bytes",
                );
            }
            if taped {
                b.metric(
                    &format!("{name}/{}/tape_peak", mode.label()),
                    m.cat_peak[0] as f64,
                    "bytes",
                );
            }
            println!(
                "  {name}/{}: peak {} (tape {}, contacts {}, solver {}, retained {})",
                mode.label(),
                fmt_bytes(m.peak),
                fmt_bytes(m.cat_peak[0]),
                fmt_bytes(m.cat_peak[1]),
                fmt_bytes(m.cat_peak[2]),
                fmt_bytes(m.cat_peak[3]),
            );
            cj.set(mode.label(), row_for(&m));
        }
        let vs_per_scene = peaks[2] as f64 / peaks[1].max(1) as f64;
        let vs_alloc = peaks[2] as f64 / peaks[0].max(1) as f64;
        b.metric(&format!("{name}/peak_ratio_shared_vs_per_scene"), vs_per_scene, "x");
        b.metric(&format!("{name}/peak_ratio_shared_vs_alloc"), vs_alloc, "x");
        cj.set("peak_ratio_shared_vs_per_scene", vs_per_scene)
            .set("peak_ratio_shared_vs_alloc", vs_alloc);
        section.set(name, cj);
    }
    merge_section("BENCH_memory.json", "batch_memory", section);
    b.finish();
}
